"""Flash attention with a recompute-based custom VJP (pure JAX).

Without this, the backward of the blockwise-attention scan saves the full
(S, S) attention probabilities per layer (~15 GB/device/layer at the
train_4k cell) — exactly the memory wall flash attention exists to remove.
The custom VJP saves only (o, lse) per row; the backward pass re-enumerates
the same static block pairs and recomputes scores from q/k blocks.

This is the lowering-path twin of the Pallas kernel in
``repro.kernels.flash_attention`` (same tiling, same online-softmax
algorithm): the Pallas kernel is the TPU-native implementation, this module
is the SPMD-shardable stand-in the dry-run compiles.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .attention import _block_pairs, NEG_INF

Array = jax.Array


def _fwd_pass(q, k, v, pairs, *, causal, window, logit_softcap, q_block,
              kv_block, scale, p_bf16=False):
    """Returns (out (B,S,H,Dv), m (B,S,H), l (B,S,H)) — fp32 stats."""
    b, sq, h, dh = q.shape
    _, skv, kv_heads, dv = v.shape
    g = h // kv_heads
    n_q = sq // q_block
    seq_offset = skv - sq

    qb = q.reshape(b, n_q, q_block, kv_heads, g, dh)
    kb = k.reshape(b, skv // kv_block, kv_block, kv_heads, dh)
    vb = v.reshape(b, skv // kv_block, kv_block, kv_heads, dv)

    o0 = jnp.zeros((b, n_q, q_block, kv_heads, g, dv), jnp.float32)
    m0 = jnp.full((b, n_q, q_block, kv_heads, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_q, q_block, kv_heads, g), jnp.float32)

    q_pos = jnp.arange(q_block)
    k_pos = jnp.arange(kv_block)

    def body(carry, pair):
        o, m, l = carry
        qi, kj = pair[0], pair[1]
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, kj, 1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, kj, 1, keepdims=False)
        s = jnp.einsum("bqkgd,btkd->bqkgt", qblk.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        qp = qi * q_block + q_pos + seq_offset
        kp = kj * kv_block + k_pos
        mask = jnp.ones((q_block, kv_block), bool)
        if causal:
            mask &= kp[None, :] <= qp[:, None]
        if window:
            mask &= kp[None, :] > qp[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        o_old = jax.lax.dynamic_index_in_dim(o, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_old, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        alpha = jnp.where(m_old <= NEG_INF / 2, 0.0,
                          jnp.exp(m_old - m_safe))
        l_new = l_old * alpha + jnp.sum(p, axis=-1)
        if p_bf16:   # §Perf: halve the dominant HBM traffic of the p@v path
            pv = jnp.einsum("bqkgt,btkd->bqkgd", p.astype(jnp.bfloat16),
                            vblk.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bqkgt,btkd->bqkgd", p,
                            vblk.astype(jnp.float32))
        o_new = o_old * alpha[..., None] + pv
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, qi, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 1)
        return (o, m, l), None

    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), pairs)
    o = o / jnp.maximum(l[..., None], 1e-30)
    out = o.reshape(b, sq, h, dv).astype(q.dtype)
    m = m.reshape(b, sq, kv_heads, g)
    l = l.reshape(b, sq, kv_heads, g)
    return out, m, l


def _bwd_pass(q, k, v, out, m, l, dout, pairs, *, causal, window,
              logit_softcap, q_block, kv_block, scale, p_bf16=False):
    b, sq, h, dh = q.shape
    _, skv, kv_heads, dv = v.shape
    g = h // kv_heads
    n_q = sq // q_block
    n_kv = skv // kv_block
    seq_offset = skv - sq

    qb = q.reshape(b, n_q, q_block, kv_heads, g, dh).astype(jnp.float32)
    kb = k.reshape(b, n_kv, kv_block, kv_heads, dh).astype(jnp.float32)
    vb = v.reshape(b, n_kv, kv_block, kv_heads, dv).astype(jnp.float32)
    do = dout.reshape(b, n_q, q_block, kv_heads, g, dv).astype(jnp.float32)
    ob = out.reshape(b, n_q, q_block, kv_heads, g, dv).astype(jnp.float32)
    mb = m.reshape(b, n_q, q_block, kv_heads, g)
    lb = l.reshape(b, n_q, q_block, kv_heads, g)
    # delta_i = sum_d do_i * o_i  (per row)
    delta = jnp.sum(do * ob, axis=-1)

    dq0 = jnp.zeros_like(qb)
    dk0 = jnp.zeros_like(kb)
    dv0 = jnp.zeros_like(vb)

    q_pos = jnp.arange(q_block)
    k_pos = jnp.arange(kv_block)

    def body(carry, pair):
        dq, dk, dv_ = carry
        qi, kj = pair[0], pair[1]
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, kj, 1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, kj, 1, keepdims=False)
        doblk = jax.lax.dynamic_index_in_dim(do, qi, 1, keepdims=False)
        mblk = jax.lax.dynamic_index_in_dim(mb, qi, 1, keepdims=False)
        lblk = jax.lax.dynamic_index_in_dim(lb, qi, 1, keepdims=False)
        dlt = jax.lax.dynamic_index_in_dim(delta, qi, 1, keepdims=False)
        s_raw = jnp.einsum("bqkgd,btkd->bqkgt", qblk, kblk) * scale
        if logit_softcap:
            t = jnp.tanh(s_raw / logit_softcap)
            s = logit_softcap * t
        else:
            s = s_raw
        qp = qi * q_block + q_pos + seq_offset
        kp = kj * kv_block + k_pos
        mask = jnp.ones((q_block, kv_block), bool)
        if causal:
            mask &= kp[None, :] <= qp[:, None]
        if window:
            mask &= kp[None, :] > qp[:, None] - window
        m_safe = jnp.where(mblk <= NEG_INF / 2, 0.0, mblk)
        l_safe = jnp.maximum(lblk, 1e-30)
        p = jnp.exp(s - m_safe[..., None]) / l_safe[..., None]
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        dp = jnp.einsum("bqkgd,btkd->bqkgt", doblk, vblk)
        ds = p * (dp - dlt[..., None])
        if logit_softcap:
            ds = ds * (1.0 - jnp.square(t))
        ds = ds * scale
        if p_bf16:
            f16 = jnp.bfloat16
            dq_blk = jnp.einsum("bqkgt,btkd->bqkgd", ds.astype(f16),
                                kblk.astype(f16),
                                preferred_element_type=jnp.float32)
            dk_blk = jnp.einsum("bqkgt,bqkgd->btkd", ds.astype(f16),
                                qblk.astype(f16),
                                preferred_element_type=jnp.float32)
            dv_blk = jnp.einsum("bqkgt,bqkgd->btkd", p.astype(f16),
                                doblk.astype(f16),
                                preferred_element_type=jnp.float32)
        else:
            dq_blk = jnp.einsum("bqkgt,btkd->bqkgd", ds, kblk)
            dk_blk = jnp.einsum("bqkgt,bqkgd->btkd", ds, qblk)
            dv_blk = jnp.einsum("bqkgt,bqkgd->btkd", p, doblk)
        dq = dq.at[:, qi].add(dq_blk)
        dk = dk.at[:, kj].add(dk_blk)
        dv_ = dv_.at[:, kj].add(dv_blk)
        return (dq, dk, dv_), None

    (dq, dk, dv_), _ = jax.lax.scan(body, (dq0, dk0, dv0), pairs)
    dq = dq.reshape(b, sq, h, dh).astype(q.dtype)
    dk = dk.reshape(b, skv, kv_heads, dh).astype(k.dtype)
    dv_ = dv_.reshape(b, skv, kv_heads, dv).astype(v.dtype)
    return dq, dk, dv_


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, window: int, logit_softcap: float,
                q_block: int, kv_block: int, scale: float,
                n_q: int, n_kv: int, seq_offset: int,
                p_bf16: bool = False):
    # NB: keep `pairs` as a host numpy array — a jnp constant created here
    # would be cached across traces and leak tracers under jax.checkpoint.
    import numpy as np
    pairs = np.asarray(
        _block_pairs(n_q, n_kv, q_block, kv_block, seq_offset, causal,
                     window), np.int32)
    kw = dict(causal=causal, window=window, logit_softcap=logit_softcap,
              q_block=q_block, kv_block=kv_block, scale=scale,
              p_bf16=p_bf16)

    @jax.custom_vjp
    def fa(q, k, v):
        out, _, _ = _fwd_pass(q, k, v, jnp.asarray(pairs), **kw)
        return out

    def fa_fwd(q, k, v):
        out, m, l = _fwd_pass(q, k, v, jnp.asarray(pairs), **kw)
        return out, (q, k, v, out, m, l)

    def fa_bwd(res, dout):
        q, k, v, out, m, l = res
        return _bwd_pass(q, k, v, out, m, l, dout, jnp.asarray(pairs),
                         **kw)

    fa.defvjp(fa_fwd, fa_bwd)
    return fa


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, logit_softcap: float = 0.0,
                    q_block: int = 512, kv_block: int = 512,
                    scale: float | None = None,
                    p_bf16: bool = False) -> Array:
    """Memory-optimal attention: O(S) residuals instead of O(S^2).

    Same signature/semantics as
    :func:`repro.models.attention.blockwise_attention`.
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    assert sq % q_block == 0 and skv % kv_block == 0
    scale = float(scale if scale is not None else dh ** -0.5)
    fa = _make_flash(bool(causal), int(window), float(logit_softcap),
                     int(q_block), int(kv_block), scale,
                     sq // q_block, skv // kv_block, skv - sq,
                     bool(p_bf16))
    return fa(q, k, v)
