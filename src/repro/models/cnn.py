"""The hand-tracking CNNs (DetNet / KeyNet) as runnable JAX models.

The semi-analytical model consumes these networks as layer *tables*
(`repro.core.handtracking`); this module makes the same networks
executable, layer-for-layer, from the geometry recorded in each
:class:`LayerSpec`, so that:

* the analytic MAC/weight counts are validated against the traced model
  (`tests/test_cnn_latency.py`);
* the end-to-end hand-tracking example runs real inference;
* the RBE int8 Pallas kernel gets a real workload: pointwise convolutions
  and the FC head execute on the quantized `rbe_matmul` path when
  ``use_rbe_int8=True`` (a 1x1 conv is a matmul over pixels — the RBE's
  native layout).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.handtracking import build_detnet, build_keynet
from repro.core.workloads import LayerKind, NNWorkload

Array = jax.Array


@dataclasses.dataclass
class HandCNN:
    """Executable twin of a hand-tracking layer table."""

    workload: NNWorkload
    input_hw: tuple[int, int]

    @classmethod
    def detnet(cls) -> "HandCNN":
        return cls(build_detnet(), (240, 320))

    @classmethod
    def keynet(cls) -> "HandCNN":
        return cls(build_keynet(), (96, 96))

    # ------------------------------------------------------------------
    def init(self, key: Array, dtype=jnp.float32) -> list[dict]:
        params = []
        keys = jax.random.split(key, len(self.workload.layers))
        for spec, k in zip(self.workload.layers, keys):
            if spec.kind is LayerKind.FC:
                w = jax.random.normal(
                    k, (spec.in_act_bytes, spec.out_act_bytes)) \
                    * spec.in_act_bytes ** -0.5
                params.append({"w": w.astype(dtype),
                               "b": jnp.zeros((spec.out_act_bytes,),
                                              dtype)})
            elif spec.kind is LayerKind.DEPTHWISE:
                w = jax.random.normal(k, (spec.k, spec.k, 1, spec.cin)) \
                    * spec.k ** -1.0
                params.append({"w": w.astype(dtype),
                               "b": jnp.zeros((spec.cin,), dtype)})
            else:
                fan = spec.k * spec.k * spec.cin
                w = jax.random.normal(
                    k, (spec.k, spec.k, spec.cin, spec.cout)) \
                    * fan ** -0.5
                params.append({"w": w.astype(dtype),
                               "b": jnp.zeros((spec.cout,), dtype)})
        return params

    def apply(self, params: list[dict], x: Array,
              use_rbe_int8: bool = False) -> Array:
        """x: (B, H, W, 1). Returns the head output (B, out).

        ``use_rbe_int8`` routes pointwise convs and the FC head through
        the RBE-adapted int8 Pallas kernel (interpret mode on CPU) when
        the dims are 128-aligned.

        Layers named ``head.*`` are parallel heads over the trunk output
        (DetNet's cls/box heads); their outputs are flattened and
        concatenated.
        """
        heads: list[Array] = []
        trunk: Array | None = None
        for spec, p in zip(self.workload.layers, params):
            if spec.name.startswith("head.") and spec.kind is not \
                    LayerKind.FC:
                if trunk is None:
                    trunk = x
                y = jax.lax.conv_general_dilated(
                    trunk, p["w"], (spec.stride, spec.stride), "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
                heads.append(y.reshape(y.shape[0], -1))
                continue
            if spec.kind is LayerKind.FC:
                b = x.shape[0]
                flat = x.reshape(b, -1)
                x = flat @ p["w"] + p["b"]
                continue
            strides = (spec.stride, spec.stride)
            if spec.kind is LayerKind.DEPTHWISE:
                y = jax.lax.conv_general_dilated(
                    x, p["w"], strides, "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    feature_group_count=spec.cin)
            elif (spec.k == 1 and use_rbe_int8
                    and spec.cin % 128 == 0 and spec.cout % 128 == 0):
                from repro.kernels.rbe_matmul import rbe_matmul
                b, h, w, c = x.shape
                y = rbe_matmul(x.reshape(b * h * w, c),
                               p["w"].reshape(c, spec.cout))
                y = y.reshape(b, h, w, spec.cout).astype(x.dtype)
            else:
                y = jax.lax.conv_general_dilated(
                    x, p["w"], strides, "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(y + p["b"])
        if heads:
            return jnp.concatenate(heads, axis=-1)
        return x

    def traced_macs(self, batch: int = 1) -> int:
        """MACs of the real traced model (validates the analytic table)."""
        total = 0
        area = self.input_hw[0] * self.input_hw[1]
        for spec in self.workload.layers:
            if spec.kind is LayerKind.FC:
                total += spec.in_act_bytes * spec.out_act_bytes
                continue
            area = math.ceil(area / (spec.stride * spec.stride)) \
                if spec.stride > 1 else area
            if spec.kind is LayerKind.DEPTHWISE:
                total += spec.k * spec.k * spec.cin * area
            else:
                total += spec.k * spec.k * spec.cin * spec.cout * area
        return total * batch

    def param_bytes(self) -> int:
        return self.workload.total_weight_bytes
