"""Transformer assembly: pattern-scanned heterogeneous layer stacks.

The stack is organized as ``first_k_dense`` unscanned prologue layers (e.g.
DeepSeek-V2's dense first layer) followed by ``R`` repeats of the config's
``block_pattern``, scanned with ``lax.scan`` over stacked per-repeat params
so the compiled HLO contains each distinct block body exactly once.

Public API:
    init_params(cfg, key)                   -> params pytree
    forward(cfg, params, batch)             -> logits (B, S, V)
    loss_fn(cfg, params, batch)             -> scalar loss (blockwise xent)
    init_cache(cfg, batch, max_len, dtype)  -> decode cache pytree
    decode_step(cfg, params, cache, tok, pos) -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .common import ModelConfig
from .layers import (compute_dtype, dense_ffn, dense_ffn_init, embed,
                     embedding_init, rmsnorm, rmsnorm_init, softcap,
                     unembed, unembed_init)
from .sharding import BATCH, MODEL, constrain

Array = jax.Array

LOSS_CHUNK = 256     # sequence-chunked cross entropy (bounds logits memory)


# ---------------------------------------------------------------------------
# Batch container
# ---------------------------------------------------------------------------


class Batch(NamedTuple):
    """Either token ids or precomputed frontend embeddings (modality stubs).

    tokens:    (B, S) int32 — ignored when embeds is not None
    embeds:    (B, S, d_model) or None — audio frames / vision patches
    positions: (B, S) int32, or (3, B, S) for M-RoPE
    labels:    (B, S) int32 next-token targets (training only)
    """
    tokens: Optional[Array] = None
    embeds: Optional[Array] = None
    positions: Optional[Array] = None
    labels: Optional[Array] = None


# ---------------------------------------------------------------------------
# Per-block init / forward / decode
# ---------------------------------------------------------------------------


def _block_init(cfg: ModelConfig, kind: str, use_moe: bool, key: Array,
                dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"norm1": rmsnorm_init(cfg.d_model)}
    if kind.startswith("attn"):
        p["mixer"] = (attn.mla_init(k1, cfg, dtype)
                      if cfg.attention_kind == "mla"
                      else attn.gqa_init(k1, cfg, dtype))
    elif kind == "mamba":
        p["mixer"] = ssm.mamba_init(k1, cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = ssm.mlstm_init(k1, cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = ssm.slstm_init(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    has_ffn = use_moe or (cfg.d_ff > 0 and kind not in ("mlstm", "slstm"))
    if has_ffn:
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = (moe_mod.moe_init(k2, cfg, dtype) if use_moe
                    else dense_ffn_init(k2, cfg.d_model, cfg.d_ff,
                                        cfg.ffn_kind, dtype))
    if cfg.post_block_norm:
        p["post_norm1"] = rmsnorm_init(cfg.d_model)
        if has_ffn:
            p["post_norm2"] = rmsnorm_init(cfg.d_model)
    return p


def _mixer_forward(cfg: ModelConfig, kind: str, params: dict, x: Array,
                   positions: Array) -> Array:
    if kind.startswith("attn"):
        window = cfg.sliding_window if kind == "attn_local" else 0
        if cfg.attention_kind == "mla":
            return attn.mla_forward(cfg, params, x, positions)
        return attn.gqa_forward(cfg, params, x, positions, window=window)
    if kind == "mamba":
        return ssm.mamba_forward(cfg, params, x)
    if kind == "mlstm":
        return ssm.mlstm_block_forward(cfg, params, x)
    if kind == "slstm":
        return ssm.slstm_block_forward(cfg, params, x)
    raise ValueError(kind)


def _block_forward(cfg: ModelConfig, kind: str, use_moe: bool, params: dict,
                   x: Array, positions: Array) -> tuple[Array, Array]:
    """Returns (x, aux_loss).

    With ``cfg.seq_parallel`` the residual stream is S-sharded over
    "model" (Megatron SP): norms and the dense FFN run token-parallel;
    the mixer (which needs the full sequence) gathers S on entry and
    scatters on exit.
    """
    aux = jnp.zeros((), jnp.float32)
    sp = cfg.seq_parallel
    res_spec = (BATCH, MODEL, None) if sp else (BATCH, None, None)
    x = constrain(x, *res_spec)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if sp:
        h = constrain(h, BATCH, None, None)      # all-gather S for mixer
    h = _mixer_forward(cfg, kind, params["mixer"], h, positions)
    if cfg.post_block_norm:
        h = rmsnorm(params["post_norm1"], h, cfg.norm_eps)
    if sp:
        h = constrain(h, BATCH, MODEL, None)     # reduce-scatter back
    x = x + h
    x = constrain(x, *res_spec)
    if "ffn" in params:
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if use_moe:
            if sp:   # MoE routes over full token sets; gather S
                h = constrain(h, BATCH, None, None)
            h, aux = moe_mod.moe_apply(cfg, params["ffn"], h)
            if sp:
                h = constrain(h, BATCH, MODEL, None)
        else:
            h = dense_ffn(params["ffn"], h, cfg.ffn_kind)
        if cfg.post_block_norm:
            h = rmsnorm(params["post_norm2"], h, cfg.norm_eps)
        x = x + h
        x = constrain(x, *res_spec)
    return x, aux


# ---------------------------------------------------------------------------
# Decode-cache plumbing
# ---------------------------------------------------------------------------


def _block_init_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      dtype) -> Any:
    if kind.startswith("attn"):
        if cfg.attention_kind == "mla":
            return attn.mla_init_cache(cfg, batch, max_len, dtype)
        return attn.gqa_init_cache(cfg, batch, max_len, dtype)
    if kind == "mamba":
        return ssm.mamba_init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return ssm.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return ssm.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def _block_decode(cfg: ModelConfig, kind: str, use_moe: bool, params: dict,
                  x: Array, cache: Any, pos: Array,
                  mla_absorb: bool) -> tuple[Array, Any]:
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind.startswith("attn"):
        window = cfg.sliding_window if kind == "attn_local" else 0
        if cfg.attention_kind == "mla":
            h, cache = attn.mla_decode(cfg, params["mixer"], h, cache, pos,
                                       absorb=mla_absorb)
        else:
            h, cache = attn.gqa_decode(cfg, params["mixer"], h, cache, pos,
                                       window=window)
    elif kind == "mamba":
        h, cache = ssm.mamba_decode(cfg, params["mixer"], h, cache)
    elif kind == "mlstm":
        h, cache = ssm.mlstm_block_decode(cfg, params["mixer"], h, cache)
    elif kind == "slstm":
        h, cache = ssm.slstm_block_decode(cfg, params["mixer"], h, cache)
    if cfg.post_block_norm:
        h = rmsnorm(params["post_norm1"], h, cfg.norm_eps)
    x = x + h
    if "ffn" in params:
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if use_moe:
            h, _ = moe_mod.moe_apply(cfg, params["ffn"], h, train=False)
        else:
            h = dense_ffn(params["ffn"], h, cfg.ffn_kind)
        if cfg.post_block_norm:
            h = rmsnorm(params["post_norm2"], h, cfg.norm_eps)
        x = x + h
    return x, cache


def _mixer_params_only(cfg, kind, use_moe, key, dtype):
    return _block_init(cfg, kind, use_moe, key, dtype)


def _pattern_moe_flags(cfg: ModelConfig) -> list[bool]:
    """Whether each pattern position uses MoE (consistent across repeats)."""
    flags = []
    for i, _ in enumerate(cfg.block_pattern):
        gidx = cfg.first_k_dense + i
        flags.append(cfg.layer_uses_moe(gidx))
    if cfg.moe is not None:
        # consistency across repeats requires pattern_len % every_k == 0
        assert len(cfg.block_pattern) % cfg.moe.every_k_layers == 0 or \
            cfg.moe.every_k_layers % len(cfg.block_pattern) == 0
    return flags


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: Array) -> dict:
    dtype = compute_dtype(cfg)
    keys = jax.random.split(key, 4 + cfg.first_k_dense)
    params: dict = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = unembed_init(keys[1], cfg.vocab_size,
                                         cfg.d_model, dtype)
    # prologue (unscanned) dense layers
    for i in range(cfg.first_k_dense):
        kind = cfg.layer_kind(i)
        params[f"pre_{i}"] = _block_init(cfg, kind, False, keys[3 + i],
                                         dtype)
    # pattern-scanned stack: per position, params stacked over repeats
    r = cfg.num_pattern_repeats
    moe_flags = _pattern_moe_flags(cfg)
    blocks = []
    pos_keys = jax.random.split(keys[2], len(cfg.block_pattern))
    for i, kind in enumerate(cfg.block_pattern):
        rep_keys = jax.random.split(pos_keys[i], r)
        stacked = jax.vmap(
            lambda kk, _kind=kind, _moe=moe_flags[i]: _block_init(
                cfg, _kind, _moe, kk, dtype))(rep_keys)
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _default_positions(cfg: ModelConfig, b: int, s: int) -> Array:
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


def _sinusoidal(positions: Array, d: int) -> Array:
    """Absolute sinusoidal position embedding (B, S) -> (B, S, d)."""
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _inputs_to_x(cfg: ModelConfig, params: dict, batch: Batch
                 ) -> tuple[Array, Array]:
    if batch.embeds is not None:
        x = batch.embeds.astype(compute_dtype(cfg))
        b, s = x.shape[:2]
    else:
        x = embed(params["embed"], batch.tokens, cfg.scale_embeddings,
                  cfg.d_model)
        b, s = batch.tokens.shape
    pos = batch.positions
    if pos is None:
        pos = _default_positions(cfg, b, s)
    if not cfg.use_rope:
        p2d = pos if pos.ndim == 2 else pos[0]
        x = x + _sinusoidal(p2d, cfg.d_model).astype(x.dtype)
    return constrain(x, BATCH, None, None), pos


def hidden_states(cfg: ModelConfig, params: dict, batch: Batch,
                  remat: bool = False) -> tuple[Array, Array]:
    """Run the stack; returns (hidden (B,S,d) after final norm, aux_loss).

    ``remat=True`` wraps each scanned super-block in ``jax.checkpoint`` with
    a dots-saveable policy — the standard activation-checkpointing setup for
    long-sequence training.
    """
    x, positions = _inputs_to_x(cfg, params, batch)
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(cfg.first_k_dense):
        kind = cfg.layer_kind(i)
        x, aux = _block_forward(cfg, kind, False, params[f"pre_{i}"], x,
                                positions)
        aux_total += aux
    moe_flags = _pattern_moe_flags(cfg)

    def superblock(carry, rep_params):
        x, aux_total = carry
        for i, kind in enumerate(cfg.block_pattern):
            x, aux = _block_forward(cfg, kind, moe_flags[i], rep_params[i],
                                    x, positions)
            aux_total += aux
        return (x, aux_total), None

    body = superblock
    if remat:
        body = jax.checkpoint(
            superblock,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                     params["blocks"])
    if cfg.seq_parallel:
        x = constrain(x, BATCH, None, None)     # gather S for the head
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total


def _logits(cfg: ModelConfig, params: dict, h: Array) -> Array:
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    return unembed(params.get("unembed"), h, cfg.final_logit_softcap, tied)


def forward(cfg: ModelConfig, params: dict, batch: Batch) -> Array:
    """Full logits — use for smoke tests / small vocab only."""
    h, _ = hidden_states(cfg, params, batch)
    return _logits(cfg, params, h)


def loss_fn(cfg: ModelConfig, params: dict, batch: Batch,
            remat: bool = False) -> Array:
    """Sequence-chunked softmax cross entropy.

    Avoids materializing (B, S, V) logits: scans over sequence chunks,
    computing per-chunk logits + logsumexp.  Essential for the 200k-vocab
    cells at 4k sequence length.
    """
    h, aux = hidden_states(cfg, params, batch, remat=remat)
    labels = batch.labels
    b, s, d = h.shape
    chunk = min(LOSS_CHUNK, s)
    while s % chunk:
        chunk //= 2
    chunk = max(chunk, 1)
    n = s // chunk
    hc = h.reshape(b, n, chunk, d).swapaxes(0, 1)         # (n, B, c, d)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)       # (n, B, c)

    def chunk_loss(carry, xs):
        hb, lb = xs
        logits = _logits(cfg, params, hb).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None],
                                   axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    if remat:   # recompute per-chunk logits in backward (saves B*c*V fp32)
        chunk_loss = jax.checkpoint(chunk_loss)
    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32),
                            (hc, lc))
    return total / (b * s) + aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    dtype = dtype or compute_dtype(cfg)
    cache: dict = {}
    for i in range(cfg.first_k_dense):
        cache[f"pre_{i}"] = _block_init_cache(cfg, cfg.layer_kind(i),
                                              batch, max_len, dtype)
    r = cfg.num_pattern_repeats
    blocks = []
    for kind in cfg.block_pattern:
        one = _block_init_cache(cfg, kind, batch, max_len, dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (r,) + a.shape).copy(), one)
        blocks.append(stacked)
    cache["blocks"] = tuple(blocks)
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                batch: Batch, pos: Array, *, mla_absorb: bool = False
                ) -> tuple[Array, dict]:
    """One-token step. batch.tokens: (B, 1) (or embeds (B, 1, d)).

    ``pos`` is the cache position to write (== number of tokens already in
    the cache).  Returns (logits (B, 1, V), new cache).
    """
    if batch.positions is None and not cfg.use_rope:
        nb = (batch.tokens if batch.tokens is not None
              else batch.embeds).shape[0]
        batch = batch._replace(
            positions=jnp.full((nb, 1), pos, jnp.int32))
    x, _ = _inputs_to_x(cfg, params, batch)
    new_cache: dict = {}
    for i in range(cfg.first_k_dense):
        kind = cfg.layer_kind(i)
        x, c = _block_decode(cfg, kind, False, params[f"pre_{i}"], x,
                             cache[f"pre_{i}"], pos, mla_absorb)
        new_cache[f"pre_{i}"] = c
    moe_flags = _pattern_moe_flags(cfg)

    def superblock(x, xs):
        rep_params, rep_cache = xs
        new_rep_cache = []
        for i, kind in enumerate(cfg.block_pattern):
            x, c = _block_decode(cfg, kind, moe_flags[i], rep_params[i], x,
                                 rep_cache[i], pos, mla_absorb)
            new_rep_cache.append(c)
        return x, tuple(new_rep_cache)

    x, blocks_cache = jax.lax.scan(superblock, x,
                                   (params["blocks"], cache["blocks"]))
    new_cache["blocks"] = blocks_cache
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(cfg, params, x), new_cache
