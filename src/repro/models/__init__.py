"""JAX model substrate: configs, layers, attention, MoE, SSM, transformer."""

from . import attention, common, layers, moe, sharding, ssm, transformer  # noqa: F401
from .common import ModelConfig, MoEConfig  # noqa: F401
from .transformer import Batch  # noqa: F401
