"""State-space / recurrent blocks: Mamba (Jamba) and xLSTM (sLSTM + mLSTM).

Training paths:
* **Mamba** — selective scan run as a sequential ``lax.scan`` over time with
  an O(B*d_inner*N) carry.  (The chunked-parallel form is a §Perf candidate;
  the sequential form keeps HLO compact and FLOP counts honest.)
* **mLSTM** — the stabilized *parallel* (quadratic) form from the xLSTM
  paper, implemented blockwise like flash attention so no (S, S) decay
  matrix is materialized.
* **sLSTM** — true recurrence (not parallelizable, per the paper);
  sequential ``lax.scan``.

Decode paths are all O(1)-state single-step updates — this is why the
``long_500k`` cell runs on the SSM/hybrid architectures only.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig

Array = jax.Array
NEG_INF = -2.0e38


# ===========================================================================
# Mamba
# ===========================================================================


class MambaState(NamedTuple):
    conv: Array   # (B, W-1, d_inner) — last W-1 post-in_proj inputs
    ssm: Array    # (B, d_inner, N)


def _mamba_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    di = cfg.ssm_expand * cfg.d_model
    return di, cfg.ssm_state_dim, cfg.ssm_conv_width, max(1, cfg.d_model // 16)


def mamba_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di, n, w, dt_rank = _mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (w, di)) * w ** -0.5
                   ).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_bcdt": (jax.random.normal(ks[2], (di, 2 * n + dt_rank))
                   * di ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, di))
                    * dt_rank ** -0.5).astype(dtype),
        "dt_bias": jnp.full((di,), math.log(math.e - 1), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * di ** -0.5
                     ).astype(dtype),
    }


def _mamba_conv_full(params: dict, xin: Array) -> Array:
    """Causal depthwise conv over (B, S, di)."""
    w = params["conv_w"].shape[0]
    pad = jnp.pad(xin, ((0, 0), (w - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, params["conv_w"][:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xin.shape[-1])
    return out + params["conv_b"]


def _mamba_ssm_inputs(cfg: ModelConfig, params: dict, xc: Array):
    di, n, _, dt_rank = _mamba_dims(cfg)
    bcdt = xc @ params["w_bcdt"]
    b_mat = bcdt[..., :n].astype(jnp.float32)
    c_mat = bcdt[..., n:2 * n].astype(jnp.float32)
    dt = jax.nn.softplus(
        bcdt[..., 2 * n:].astype(jnp.float32) @ params["dt_proj"].astype(
            jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                   # (di, N)
    return a, b_mat, c_mat, dt


def mamba_forward(cfg: ModelConfig, params: dict, x: Array) -> Array:
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    di, n, w, _ = _mamba_dims(cfg)
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_mamba_conv_full(params, xin))
    a, b_mat, c_mat, dt = _mamba_ssm_inputs(cfg, params, xc)
    x32 = xc.astype(jnp.float32)

    def step(h, inputs):
        xt, bt, ct, dtt = inputs            # (B,di) (B,N) (B,N) (B,di)
        da = jnp.exp(dtt[..., None] * a)                    # (B, di, N)
        dbx = (dtt * xt)[..., None] * bt[:, None, :]        # (B, di, N)
        h = da * h + dbx
        yt = jnp.einsum("bdn,bn->bd", h, ct)
        return h, yt

    h0 = jnp.zeros((b, di, n), jnp.float32)
    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(b_mat, 1, 0),
          jnp.moveaxis(c_mat, 1, 0), jnp.moveaxis(dt, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + params["D"] * x32          # (B, S, di)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    return y @ params["out_proj"]


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    di, n, w, _ = _mamba_dims(cfg)
    return MambaState(conv=jnp.zeros((batch, w - 1, di), dtype),
                      ssm=jnp.zeros((batch, di, n), jnp.float32))


def mamba_decode(cfg: ModelConfig, params: dict, x: Array,
                 state: MambaState) -> tuple[Array, MambaState]:
    """x: (B, 1, d); O(1) single-step update."""
    b = x.shape[0]
    di, n, w, _ = _mamba_dims(cfg)
    xz = x[:, 0] @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                       # (B, di)
    window = jnp.concatenate([state.conv, xin[:, None]], axis=1)  # (B,W,di)
    xc = jax.nn.silu(
        jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32))
        + params["conv_b"].astype(jnp.float32)).astype(x.dtype)
    a, b_mat, c_mat, dt = _mamba_ssm_inputs(cfg, params, xc[:, None])
    bt, ct, dtt = b_mat[:, 0], c_mat[:, 0], dt[:, 0]
    da = jnp.exp(dtt[..., None] * a)
    dbx = (dtt * xc.astype(jnp.float32))[..., None] * bt[:, None, :]
    h = da * state.ssm + dbx
    yt = jnp.einsum("bdn,bn->bd", h, ct) + params["D"] * xc.astype(
        jnp.float32)
    y = (yt.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    return y[:, None], MambaState(conv=window[:, 1:], ssm=h)


# ===========================================================================
# mLSTM (xLSTM) — parallel blockwise training form + recurrent decode
# ===========================================================================


class MLSTMState(NamedTuple):
    c: Array    # (B, H, hd, hd) matrix memory
    n: Array    # (B, H, hd)
    m: Array    # (B, H) stabilizer


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    di = 2 * cfg.d_model
    h = cfg.num_heads
    return di, h, di // h


def mlstm_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di, h, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    s, si = d ** -0.5, di ** -0.5
    return {
        "w_up": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "w_q": (jax.random.normal(ks[1], (di, di)) * si).astype(dtype),
        "w_k": (jax.random.normal(ks[2], (di, di)) * si).astype(dtype),
        "w_v": (jax.random.normal(ks[3], (di, di)) * si).astype(dtype),
        "w_ig": (jax.random.normal(ks[4], (di, h)) * si).astype(jnp.float32),
        "b_ig": jnp.zeros((h,), jnp.float32),
        "w_fg": (jax.random.normal(ks[5], (di, h)) * si).astype(jnp.float32),
        "b_fg": jnp.full((h,), 3.0, jnp.float32),   # open forget gates
        "w_down": (jax.random.normal(ks[6], (di, d)) * si).astype(dtype),
    }


def mlstm_parallel(q: Array, k: Array, v: Array, log_i: Array,
                   log_f: Array, q_block: int = 256,
                   kv_block: int = 256) -> Array:
    """Stabilized parallel mLSTM (xLSTM eq. 19-27), blockwise.

    q/k/v: (B, S, H, hd); log_i/log_f: (B, S, H).
    D_ij = exp(F_i - F_j + log_i_j) for j <= i, F_t = cumsum(log_f).
    h_i = sum_j (q_i k_j / sqrt(hd)) D~_ij v_j / max(|den|, exp(-m_i)).
    """
    b, s, h, hd = q.shape
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    assert s % q_block == 0 and s % kv_block == 0
    n_q, n_kv = s // q_block, s // kv_block
    scale = hd ** -0.5

    f_cum = jnp.cumsum(log_f.astype(jnp.float32), axis=1)     # (B, S, H)

    pairs = jnp.asarray(
        [(i, j) for i in range(n_q) for j in range(n_kv)
         if j * kv_block <= (i + 1) * q_block - 1], jnp.int32)

    qb = q.reshape(b, n_q, q_block, h, hd)
    kb = k.reshape(b, n_kv, kv_block, h, hd)
    vb = v.reshape(b, n_kv, kv_block, h, hd)
    fq = f_cum.reshape(b, n_q, q_block, h)
    fk = f_cum.reshape(b, n_kv, kv_block, h)
    ik = log_i.astype(jnp.float32).reshape(b, n_kv, kv_block, h)

    o0 = jnp.zeros((b, n_q, q_block, h, hd), jnp.float32)
    l0 = jnp.zeros((b, n_q, q_block, h), jnp.float32)
    m0 = jnp.full((b, n_q, q_block, h), NEG_INF, jnp.float32)

    def body(carry, pair):
        o, l, m = carry
        qi, kj = pair[0], pair[1]
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, kj, 1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, kj, 1, keepdims=False)
        fqb = jax.lax.dynamic_index_in_dim(fq, qi, 1, keepdims=False)
        fkb = jax.lax.dynamic_index_in_dim(fk, kj, 1, keepdims=False)
        ikb = jax.lax.dynamic_index_in_dim(ik, kj, 1, keepdims=False)
        # decay logits (B, qb, kb, H)
        logd = (fqb[:, :, None, :] - fkb[:, None, :, :]
                + ikb[:, None, :, :])
        qpos = qi * q_block + jnp.arange(q_block)
        kpos = kj * kv_block + jnp.arange(kv_block)
        mask = kpos[None, :] <= qpos[:, None]
        logd = jnp.where(mask[None, :, :, None], logd, NEG_INF)
        m_blk = jnp.max(logd, axis=2)                          # (B,qb,H)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        o_old = jax.lax.dynamic_index_in_dim(o, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_old, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        dmat = jnp.exp(logd - m_safe[:, :, None, :])
        s_qk = jnp.einsum("bqhd,bthd->bqth", qblk.astype(jnp.float32),
                          kblk.astype(jnp.float32)) * scale
        a = s_qk * dmat                                        # (B,qb,kb,H)
        alpha = jnp.where(m_old <= NEG_INF / 2, 0.0,
                          jnp.exp(m_old - m_safe))
        l_new = l_old * alpha + jnp.sum(a, axis=2)
        o_new = o_old * alpha[..., None] + jnp.einsum(
            "bqth,bthd->bqhd", a, vblk.astype(jnp.float32))
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, qi, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 1)
        return (o, l, m), None

    (o, l, m), _ = jax.lax.scan(body, (o0, l0, m0), pairs)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    den = jnp.maximum(jnp.abs(l), jnp.exp(-m_safe))[..., None]
    out = o / den
    return out.reshape(b, s, h, hd).astype(q.dtype)


def mlstm_block_forward(cfg: ModelConfig, params: dict, x: Array) -> Array:
    b, s, d = x.shape
    di, h, hd = _mlstm_dims(cfg)
    up = x @ params["w_up"]
    xin, gate = jnp.split(up, 2, axis=-1)                     # (B,S,di)
    q = (xin @ params["w_q"]).reshape(b, s, h, hd)
    k = (xin @ params["w_k"]).reshape(b, s, h, hd)
    v = (xin @ params["w_v"]).reshape(b, s, h, hd)
    x32 = xin.astype(jnp.float32)
    log_i = x32 @ params["w_ig"] + params["b_ig"]             # (B,S,H)
    log_f = jax.nn.log_sigmoid(x32 @ params["w_fg"] + params["b_fg"])
    ht = mlstm_parallel(q, k, v, log_i, log_f)
    y = ht.reshape(b, s, di) * jax.nn.silu(gate)
    return y @ params["w_down"]


def mlstm_init_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    _, h, hd = _mlstm_dims(cfg)
    return MLSTMState(c=jnp.zeros((batch, h, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, h, hd), jnp.float32),
                      m=jnp.full((batch, h), -1e30, jnp.float32))


def mlstm_block_decode(cfg: ModelConfig, params: dict, x: Array,
                       state: MLSTMState) -> tuple[Array, MLSTMState]:
    """x: (B, 1, d). Recurrent stabilized update (xLSTM eq. 19-27)."""
    b = x.shape[0]
    di, h, hd = _mlstm_dims(cfg)
    up = x[:, 0] @ params["w_up"]
    xin, gate = jnp.split(up, 2, axis=-1)
    q = (xin @ params["w_q"]).reshape(b, h, hd).astype(jnp.float32)
    k = (xin @ params["w_k"]).reshape(b, h, hd).astype(jnp.float32)
    v = (xin @ params["w_v"]).reshape(b, h, hd).astype(jnp.float32)
    x32 = xin.astype(jnp.float32)
    log_i = x32 @ params["w_ig"] + params["b_ig"]             # (B,H)
    log_f = jax.nn.log_sigmoid(x32 @ params["w_fg"] + params["b_fg"])
    m_new = jnp.maximum(log_f + state.m, log_i)
    f_sc = jnp.exp(log_f + state.m - m_new)
    i_sc = jnp.exp(log_i - m_new)
    c = f_sc[..., None, None] * state.c + \
        i_sc[..., None, None] * v[..., :, None] * k[..., None, :]
    n = f_sc[..., None] * state.n + i_sc[..., None] * k
    q = q * hd ** -0.5
    num = jnp.einsum("bhvk,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))
    ht = (num / den[..., None]).reshape(b, di).astype(x.dtype)
    y = ht * jax.nn.silu(gate)
    return (y @ params["w_down"])[:, None], MLSTMState(c, n, m_new)


# ===========================================================================
# sLSTM — sequential exponential-gated LSTM with per-head recurrence
# ===========================================================================


class SLSTMState(NamedTuple):
    c: Array    # (B, H, hd)
    n: Array    # (B, H, hd)
    h: Array    # (B, H, hd)
    m: Array    # (B, H, hd) stabilizer


def _slstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    h = cfg.num_heads
    return h, cfg.d_model // h


def slstm_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h, hd = _slstm_dims(cfg)
    ks = jax.random.split(key, 9)
    s = d ** -0.5
    p = {}
    for idx, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = (jax.random.normal(ks[idx], (d, d)) * s).astype(dtype)
        p[f"r_{g}"] = (jax.random.normal(ks[idx + 4], (h, hd, hd))
                       * hd ** -0.5).astype(dtype)
        p[f"b_{g}"] = (jnp.full((d,), 1.0, jnp.float32) if g == "f"
                       else jnp.zeros((d,), jnp.float32))
    p["w_out"] = (jax.random.normal(ks[8], (d, d)) * s).astype(dtype)
    return p


def _slstm_step(params: dict, xt: Array, state: SLSTMState
                ) -> tuple[Array, SLSTMState]:
    """xt: (B, d). Exponential-gated update (xLSTM eqs. 8-18)."""
    b = xt.shape[0]
    h_heads, hd = state.h.shape[1], state.h.shape[2]
    d = h_heads * hd

    def gate(g):
        wx = (xt @ params[f"w_{g}"]).reshape(b, h_heads, hd)
        rh = jnp.einsum("bhk,hkj->bhj", state.h.astype(xt.dtype),
                        params[f"r_{g}"])
        bb = params[f"b_{g}"].reshape(h_heads, hd)
        return (wx + rh).astype(jnp.float32) + bb

    z = jnp.tanh(gate("z"))
    log_i = gate("i")
    log_f = jax.nn.log_sigmoid(gate("f"))
    o = jax.nn.sigmoid(gate("o"))
    m_new = jnp.maximum(log_f + state.m, log_i)
    i_sc = jnp.exp(log_i - m_new)
    f_sc = jnp.exp(log_f + state.m - m_new)
    c = f_sc * state.c + i_sc * z
    n = f_sc * state.n + i_sc
    h_new = o * c / jnp.maximum(n, 1e-6)
    return h_new.reshape(b, d), SLSTMState(c, n, h_new, m_new)


def slstm_block_forward(cfg: ModelConfig, params: dict, x: Array) -> Array:
    b, s, d = x.shape
    hh, hd = _slstm_dims(cfg)
    state = slstm_init_state(cfg, b)

    def step(st, xt):
        y, st = _slstm_step(params, xt, st)
        return st, y

    _, ys = jax.lax.scan(step, state, jnp.moveaxis(x, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    return y @ params["w_out"]


def slstm_init_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    hh, hd = _slstm_dims(cfg)
    z = jnp.zeros((batch, hh, hd), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -1e30))


def slstm_block_decode(cfg: ModelConfig, params: dict, x: Array,
                       state: SLSTMState) -> tuple[Array, SLSTMState]:
    y, state = _slstm_step(params, x[:, 0], state)
    return (y.astype(x.dtype) @ params["w_out"])[:, None], state
