"""Elementary layers: norms, RoPE/M-RoPE, FFNs, embeddings, softcap."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig

Array = jax.Array


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    """RMS norm with (1+scale) parameterization (Gemma/LLaMA style)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"])).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)           # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: Tuple[int, ...]) -> Array:
    """Multimodal RoPE (Qwen2-VL): the half-dim frequency bands are split
    into (temporal, height, width) sections, each rotated by its own
    position stream.

    x: (B, S, H, D); positions3: (3, B, S) int32.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)            # (half,)
    # build a per-frequency position by selecting the t/h/w stream
    sec_id = jnp.repeat(
        jnp.arange(len(sections)),
        jnp.asarray(sections),
        total_repeat_length=half)                           # (half,)
    pos = positions3.astype(jnp.float32)                    # (3, B, S)
    pos_per_freq = jnp.take(pos, sec_id, axis=0)            # (half, B, S)
    ang = jnp.einsum("fbs,f->bsf", pos_per_freq, freqs)     # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Softcap (Gemma-2)
# ---------------------------------------------------------------------------


def softcap(x: Array, cap: float) -> Array:
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------


def dense_ffn_init(key: Array, d: int, d_ff: int, kind: str,
                   dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(k2, (d, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d)) * s_out).astype(dtype),
    }
    if kind == "swiglu":
        p["w_gate"] = (jax.random.normal(k1, (d, d_ff)) * s_in).astype(dtype)
    return p


def dense_ffn(params: dict, x: Array, kind: str) -> Array:
    up = x @ params["w_up"]
    if kind == "swiglu":
        gate = jax.nn.silu(x @ params["w_gate"])
        h = gate * up
    else:
        h = jax.nn.gelu(up)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(key: Array, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * d ** -0.5
                      ).astype(dtype)}


def embed(params: dict, tokens: Array, scale: bool, d: int) -> Array:
    x = jnp.take(params["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(d ** 0.5, x.dtype)
    return x


def unembed_init(key: Array, vocab: int, d: int, dtype) -> dict:
    return {"w": (jax.random.normal(key, (d, vocab)) * d ** -0.5
                  ).astype(dtype)}


def unembed(params: dict, x: Array, cap: float = 0.0,
            tied_table: Optional[Array] = None) -> Array:
    if tied_table is not None:
        logits = x @ tied_table.T
    else:
        logits = x @ params["w"]
    return softcap(logits, cap)
