"""Attention: GQA (RoPE, windows, softcap, M-RoPE) and MLA (DeepSeek-V2).

Two execution paths:

* **train/prefill** — block-sparse online-softmax attention in pure JAX
  (``blockwise_attention``).  Only (q-block, kv-block) pairs that intersect
  the causal/window mask are enumerated — *statically* — so compiled FLOPs
  and memory match what a fused TPU kernel would do (the Pallas twin lives
  in ``repro.kernels.flash_attention``).  This keeps the 32k-token cells
  compilable: no (S, S) score tensor is ever materialized.
* **decode** — single-token attention against a preallocated KV cache with
  position masking.

MLA keeps the compressed ``c_kv`` + shared rope key as the cache (the
paper-adjacent trick: ship/store the compressed representation, expand near
compute).  The decode path supports both the naive (expand-then-attend) and
the absorbed (attend-in-latent-space) formulations.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import apply_mrope, apply_rope, rmsnorm, softcap

Array = jax.Array

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Block-sparse online-softmax attention (pure JAX, statically masked pairs)
# ---------------------------------------------------------------------------


def _block_pairs(n_q: int, n_kv: int, q_block: int, kv_block: int,
                 seq_offset: int, causal: bool,
                 window: int) -> list[tuple[int, int]]:
    """Statically enumerate (q_block, kv_block) pairs intersecting the mask.

    Works in absolute positions, so unequal block sizes and
    prefix-offset queries (``seq_offset = skv - sq``) are handled.  Pairs
    are ordered by q block then kv block, which the online-softmax update
    requires.  ``window`` prunes kv blocks entirely below the sliding
    window.
    """
    pairs = []
    for qi in range(n_q):
        q_lo = qi * q_block + seq_offset        # first absolute q position
        q_hi = q_lo + q_block - 1               # last absolute q position
        for kj in range(n_kv):
            k_lo = kj * kv_block
            k_hi = k_lo + kv_block - 1
            if causal and k_lo > q_hi:
                continue                        # fully above the diagonal
            if window and k_hi <= q_lo - window:
                continue                        # fully below the window
            pairs.append((qi, kj))
    return pairs


def blockwise_attention(q: Array, k: Array, v: Array, *,
                        causal: bool = True,
                        window: int = 0,
                        logit_softcap: float = 0.0,
                        q_block: int = 512,
                        kv_block: int = 512,
                        scale: float | None = None) -> Array:
    """q: (B, Sq, H, Dh); k/v: (B, Skv, KV, Dh) with H = G*KV.

    Returns (B, Sq, H, Dv).  Flash-attention algorithm expressed with
    ``lax.scan`` over statically-enumerated block pairs.
    """
    b, sq, h, dh = q.shape
    _, skv, kv_heads, dv = v.shape
    g = h // kv_heads
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    assert sq % q_block == 0 and skv % kv_block == 0
    n_q, n_kv = sq // q_block, skv // kv_block
    scale = scale if scale is not None else dh ** -0.5

    pairs = jnp.asarray(
        _block_pairs(n_q, n_kv, q_block, kv_block, skv - sq, causal,
                     window), jnp.int32)

    qb = q.reshape(b, n_q, q_block, kv_heads, g, dh)
    kb = k.reshape(b, n_kv, kv_block, kv_heads, dh)
    vb = v.reshape(b, n_kv, kv_block, kv_heads, dv)

    o0 = jnp.zeros((b, n_q, q_block, kv_heads, g, dv), jnp.float32)
    m0 = jnp.full((b, n_q, q_block, kv_heads, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_q, q_block, kv_heads, g), jnp.float32)

    q_pos_base = jnp.arange(q_block)
    k_pos_base = jnp.arange(kv_block)
    seq_offset = skv - sq  # decode-style alignment (q at the sequence end)

    def body(carry, pair):
        o, m, l = carry
        qi, kj = pair[0], pair[1]
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, axis=1, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, kj, axis=1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, kj, axis=1, keepdims=False)
        # scores: (b, q_block, kv, g, kv_block)
        s = jnp.einsum("bqkgd,btkd->bqkgt", qblk.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        qpos = qi * q_block + q_pos_base + seq_offset      # (q_block,)
        kpos = kj * kv_block + k_pos_base                  # (kv_block,)
        mask = jnp.ones((q_block, kv_block), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)                        # (b,qb,kv,g)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        o_old = jax.lax.dynamic_index_in_dim(o, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_old, m_blk)
        # guard fully-masked rows (m_new == NEG_INF)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        alpha = jnp.where(m_old <= NEG_INF / 2, 0.0,
                          jnp.exp(m_old - m_safe))
        l_new = l_old * alpha + jnp.sum(p, axis=-1)
        o_new = o_old * alpha[..., None] + jnp.einsum(
            "bqkgt,btkd->bqkgd", p, vblk.astype(jnp.float32))
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, qi, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 1)
        return (o, m, l), None

    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), pairs)
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(b, sq, h, dv).astype(q.dtype)


def full_attention_reference(q, k, v, *, causal=True, window=0,
                             logit_softcap=0.0, scale=None):
    """O(S^2)-memory oracle used by tests (small shapes only)."""
    b, sq, h, dh = q.shape
    _, skv, kv_heads, dv = v.shape
    g = h // kv_heads
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(b, sq, kv_heads, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bqkgt", qg,
                   k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qpos = jnp.arange(sq) + (skv - sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0,
                     logit_softcap=0.0, scale=None):
    """Single-token attention over a preallocated cache.

    q: (B, 1, H, Dh); caches: (B, S_max, KV, Dh); cache_len: () int32 —
    number of valid positions INCLUDING the token just inserted.
    """
    b, _, h, dh = q.shape
    _, s_max, kv_heads, dv = v_cache.shape
    g = h // kv_heads
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(b, kv_heads, g, dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg,
                   k_cache.astype(jnp.float32)) * scale
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    kpos = jnp.arange(s_max)
    valid = kpos < cache_len
    if window:
        valid &= kpos > cache_len - 1 - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def gqa_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, h, kvh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "w_q": (jax.random.normal(ks[0], (d, h, hd)) * s).astype(dtype),
        "w_k": (jax.random.normal(ks[1], (d, kvh, hd)) * s).astype(dtype),
        "w_v": (jax.random.normal(ks[2], (d, kvh, hd)) * s).astype(dtype),
        "w_o": (jax.random.normal(ks[3], (h, hd, d))
                * (h * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h, hd), dtype)
        p["b_k"] = jnp.zeros((kvh, hd), dtype)
        p["b_v"] = jnp.zeros((kvh, hd), dtype)
    return p


def _rope_or_mrope(cfg: ModelConfig, x: Array, positions: Array) -> Array:
    if not cfg.use_rope:
        return x
    if cfg.mrope_sections:
        if positions.ndim == 2:     # text-only: t=h=w position
            positions = jnp.broadcast_to(positions[None],
                                         (3,) + positions.shape)
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def gqa_forward(cfg: ModelConfig, params: dict, x: Array,
                positions: Array, *, window: int = 0,
                q_block: int = 512, kv_block: int = 512) -> Array:
    """Full-sequence (train / prefill) GQA.

    Uses the recompute-based flash VJP so training never materializes or
    saves (S, S) probability tensors.
    """
    from .flash import flash_attention  # local import: avoids import cycle
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = _rope_or_mrope(cfg, q, positions)
    k = _rope_or_mrope(cfg, k, positions)
    o = flash_attention(q, k, v, causal=True, window=window,
                        logit_softcap=cfg.attn_logit_softcap,
                        q_block=q_block, kv_block=kv_block,
                        p_bf16=cfg.attn_p_bf16)
    return jnp.einsum("bshk,hkd->bsd", o, params["w_o"])


class KVCache(NamedTuple):
    k: Array       # (B, S_max, KV, Dh)
    v: Array       # (B, S_max, KV, Dh)


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype) -> KVCache:
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return KVCache(k=jnp.zeros((batch, max_len, kvh, hd), dtype),
                   v=jnp.zeros((batch, max_len, kvh, hd), dtype))


def gqa_decode(cfg: ModelConfig, params: dict, x: Array, cache: KVCache,
               pos: Array, *, window: int = 0) -> tuple[Array, KVCache]:
    """One-token decode. x: (B, 1, d); pos: () int32 index to write."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    pos_b = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q = _rope_or_mrope(cfg, q, pos_b)
    k = _rope_or_mrope(cfg, k, pos_b)
    kc = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, pos, 0, 0))
    o = decode_attention(q, kc, vc, pos + 1, window=window,
                         logit_softcap=cfg.attn_logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", o, params["w_o"])
    return out, KVCache(kc, vc)


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    p: dict = {}
    if cfg.q_lora_rank:
        p["w_dq"] = (jax.random.normal(ks[0], (d, cfg.q_lora_rank))
                     * s).astype(dtype)
        p["q_norm"] = {"scale": jnp.zeros((cfg.q_lora_rank,), jnp.float32)}
        p["w_uq"] = (jax.random.normal(ks[1], (cfg.q_lora_rank, h, qk))
                     * cfg.q_lora_rank ** -0.5).astype(dtype)
    else:
        p["w_q"] = (jax.random.normal(ks[1], (d, h, qk)) * s).astype(dtype)
    p["w_dkv"] = (jax.random.normal(ks[2], (d, cfg.kv_lora_rank))
                  * s).astype(dtype)
    p["kv_norm"] = {"scale": jnp.zeros((cfg.kv_lora_rank,), jnp.float32)}
    p["w_kr"] = (jax.random.normal(ks[3], (d, cfg.qk_rope_dim))
                 * s).astype(dtype)
    p["w_uk"] = (jax.random.normal(ks[4], (cfg.kv_lora_rank, h,
                                           cfg.qk_nope_dim))
                 * cfg.kv_lora_rank ** -0.5).astype(dtype)
    p["w_uv"] = (jax.random.normal(ks[5], (cfg.kv_lora_rank, h,
                                           cfg.v_head_dim))
                 * cfg.kv_lora_rank ** -0.5).astype(dtype)
    p["w_o"] = (jax.random.normal(ks[6], (h, cfg.v_head_dim, d))
                * (h * cfg.v_head_dim) ** -0.5).astype(dtype)
    return p


def _mla_q(cfg: ModelConfig, params: dict, x: Array,
           positions: Array) -> tuple[Array, Array]:
    """Returns (q_nope (B,S,H,nope), q_rope (B,S,H,rope))."""
    if cfg.q_lora_rank:
        cq = rmsnorm(params["q_norm"], x @ params["w_dq"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(cfg: ModelConfig, params: dict, x: Array,
                positions: Array, *, q_block: int = 512,
                kv_block: int = 512) -> Array:
    """Full-sequence MLA (train / prefill): expand latents, then attend."""
    q_nope, q_rope = _mla_q(cfg, params, x, positions)
    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"], cfg.norm_eps)
    k_pe = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                      cfg.rope_theta)                       # (B,S,1,rope)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    from .flash import flash_attention  # local import: avoids import cycle
    h = cfg.num_heads
    k_pe_b = jnp.broadcast_to(k_pe, k_pe.shape[:2] + (h, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    o = flash_attention(q, k, v, causal=True,
                        q_block=q_block, kv_block=kv_block,
                        scale=(cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5,
                        p_bf16=cfg.attn_p_bf16)
    return jnp.einsum("bshk,hkd->bsd", o, params["w_o"])


class MLACache(NamedTuple):
    c_kv: Array    # (B, S_max, kv_lora)
    k_pe: Array    # (B, S_max, rope_dim)


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        k_pe=jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype))


def mla_decode(cfg: ModelConfig, params: dict, x: Array, cache: MLACache,
               pos: Array, *, absorb: bool = False
               ) -> tuple[Array, MLACache]:
    """One-token MLA decode.

    ``absorb=False`` — naive: expand k/v for the whole cache every step
    (O(S * kv_lora * H * dh) per step).
    ``absorb=True``  — absorbed: fold w_uk into q and attend directly in
    the compressed latent space (O(S * kv_lora) per head) — the §Perf
    optimization for the MLA decode cells.
    """
    b = x.shape[0]
    pos_b = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, params, x, pos_b)
    c_new = rmsnorm(params["kv_norm"], x @ params["w_dkv"], cfg.norm_eps)
    kpe_new = apply_rope((x @ params["w_kr"])[:, :, None, :], pos_b,
                         cfg.rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, pos, 0))
    k_pe = jax.lax.dynamic_update_slice(
        cache.k_pe, kpe_new.astype(cache.k_pe.dtype), (0, pos, 0))
    s_max = c_kv.shape[1]
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    kpos = jnp.arange(s_max)
    valid = kpos <= pos
    if absorb:
        # q' = q_nope @ w_uk  -> latent space: (B,1,H,kv_lora)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, params["w_uk"])
        s = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        c_kv.astype(jnp.float32))
             + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                          k_pe.astype(jnp.float32))) * scale
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", p,
                         c_kv.astype(jnp.float32))     # latent context
        o = jnp.einsum("bshr,rhk->bshk", ctx.astype(x.dtype),
                       params["w_uv"])
    else:
        k_nope = jnp.einsum("btr,rhn->bthn", c_kv, params["w_uk"])
        v = jnp.einsum("btr,rhk->bthk", c_kv, params["w_uv"])
        s = (jnp.einsum("bshn,bthn->bhst", q_nope.astype(jnp.float32),
                        k_nope.astype(jnp.float32))
             + jnp.einsum("bshn,btn->bhst", q_rope.astype(jnp.float32),
                          k_pe.astype(jnp.float32))) * scale
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,bthk->bshk", p,
                       v.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, params["w_o"])
    return out, MLACache(c_kv, k_pe)
