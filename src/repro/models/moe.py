"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Dispatch is **scatter-based**, not one-hot-einsum-based: tokens are grouped,
ranked within (group, expert) via a cumulative count, and scattered into a
static ``(G, E, C, d)`` buffer.  This keeps compiled FLOPs equal to the
*active-expert* FLOPs (x capacity factor) — a one-hot dispatch einsum would
add O(T*E*C*d) fake FLOPs that poison the roofline analysis.

Sharding: tokens/groups ride the batch ("data") axis, experts ride the
"model" axis (expert parallelism).  The combine step's gather over the
expert-sharded buffer induces one all-reduce over the model axis per MoE
layer — the same collective a tensor-parallel dense FFN would need.

Supports the assigned variants:
* Arctic    — 128 experts top-2 **plus a dense residual FFN** in parallel;
* DeepSeek  — 160 routed top-6 **plus 2 shared (always-on) experts**;
* Jamba     — 16 experts top-2 on every 2nd layer.

For single-token decode (S == 1) the whole batch forms one group so the
capacity math stays tight and dropless-ish (see ``_group_tokens``).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, MoEConfig
from .layers import dense_ffn, dense_ffn_init
from .sharding import BATCH, MODEL, constrain

Array = jax.Array

GROUP_SIZE = 512     # tokens per routing group (training/prefill)


def moe_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    assert m is not None
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 6)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
    }
    if m.num_shared_experts:
        p["shared"] = dense_ffn_init(ks[4], d, f * m.num_shared_experts,
                                     cfg.ffn_kind, dtype)
    if m.dense_residual:
        p["residual"] = dense_ffn_init(ks[5], d, cfg.d_ff, cfg.ffn_kind,
                                       dtype)
    return p


def _group_tokens(x2d: Array, m: MoEConfig) -> tuple[Array, int]:
    """Reshape (T, d) -> (G, gs, d) with a capacity-friendly group size."""
    t = x2d.shape[0]
    gs = min(GROUP_SIZE, t)
    # groups must tile the token count
    while t % gs:
        gs //= 2
    gs = max(gs, 1)
    return x2d.reshape(t // gs, gs, x2d.shape[1]), gs


def _capacity(gs: int, m: MoEConfig) -> int:
    c = math.ceil(gs * m.top_k * m.capacity_factor / m.num_experts)
    return max(1, min(c, gs))


def moe_apply(cfg: ModelConfig, params: dict, x: Array,
              train: bool = True) -> tuple[Array, Array]:
    """x: (B, S, d). Returns (out (B, S, d), aux_loss ())."""
    m = cfg.moe
    assert m is not None
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    x2d = x.reshape(b * s, d)
    xg, gs = _group_tokens(x2d, m)                    # (G, gs, d)
    g = xg.shape[0]
    cap = _capacity(gs, m)

    # ---- routing (fp32) ----
    logits = xg.astype(jnp.float32) @ params["router"]         # (G, gs, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (G, gs, k)
    top_p = top_p / jnp.maximum(
        jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # ---- aux load-balancing loss (Switch-style) ----
    me = jnp.mean(probs, axis=1)                               # (G, E)
    one_hot_top1 = jax.nn.one_hot(top_e[..., 0], e)
    ce = jnp.mean(one_hot_top1, axis=1)                        # (G, E)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * e * m.aux_loss_weight

    # ---- rank within (group, expert): position = #earlier picks of e ----
    sel = jax.nn.one_hot(top_e, e, dtype=jnp.int32)            # (G, gs, k, E)
    sel_flat = sel.reshape(g, gs * k, e)
    pos_flat = jnp.cumsum(sel_flat, axis=1) - sel_flat         # exclusive
    pos = jnp.sum(pos_flat.reshape(g, gs, k, e) * sel, axis=-1)  # (G, gs, k)
    keep = pos < cap

    # ---- scatter tokens into the (G, E, C, d) expert buffer ----
    buf = jnp.zeros((g, e, cap, d), x.dtype)
    gi = jnp.broadcast_to(jnp.arange(g)[:, None, None], (g, gs, k))
    slot = jnp.where(keep, pos, cap - 1)
    src = jnp.broadcast_to(xg[:, :, None, :], (g, gs, k, d))
    src = jnp.where(keep[..., None], src, 0)
    buf = buf.at[gi, top_e, slot].add(src, mode="drop")
    buf = constrain(buf, BATCH, MODEL, None, None)

    # ---- expert FFN: einsum over the expert-sharded buffer ----
    if cfg.moe_partial_sum:
        # §Perf "a2a-reshard" dispatch: scatter locally (G stays on the
        # batch axes — cheap), then RESHARD the buffer so groups gather
        # while d shards over "data" (a dim-swap all-to-all, buffer-sized
        # traffic).  Expert weights are FSDP-sharded on their contraction
        # dims (see launch/partitioning.py), so both expert einsums
        # contract locally and weight *gradients* are complete per shard —
        # no weight-sized all-gathers or fp32 grad all-reduces, which is
        # what made the baseline collective-bound.
        buf = constrain(buf, None, MODEL, None, "data")
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    if cfg.moe_partial_sum:
        up = constrain(up, None, MODEL, None, "data")   # reduce-scatter f
    if cfg.ffn_kind == "swiglu":
        gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf,
                                      params["w_gate"]))
        if cfg.moe_partial_sum:
            gate = constrain(gate, None, MODEL, None, "data")
        h = gate * up
    else:
        h = jax.nn.gelu(up)
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    if cfg.moe_partial_sum:
        out_buf = constrain(out_buf, None, MODEL, None, "data")
    else:
        out_buf = constrain(out_buf, BATCH, MODEL, None, None)

    # ---- combine: gather each token's k slots, weight by router prob ----
    gathered = out_buf[gi, top_e, slot]                        # (G, gs, k, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    yg = jnp.einsum("gskd,gsk->gsd", gathered,
                    top_p.astype(gathered.dtype))
    y = yg.reshape(b, s, d)
    y = constrain(y, BATCH, None, None)

    # ---- always-on branches ----
    if "shared" in params:
        y = y + dense_ffn(params["shared"], x, cfg.ffn_kind)
    if "residual" in params:
        y = y + dense_ffn(params["residual"], x, cfg.ffn_kind)
    return y, aux.astype(jnp.float32)


def moe_ref(cfg: ModelConfig, params: dict, x: Array) -> Array:
    """Dense oracle: every token through its top-k experts, no capacity.

    O(T * k * expert) compute via gathered per-token expert weights — only
    usable at test sizes, but drop-free: used to validate ``moe_apply`` up
    to capacity drops.
    """
    m = cfg.moe
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    logits = x2d.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    wg = params["w_gate"][top_e]        # (T, k, d, f)
    wu = params["w_up"][top_e]
    wd = params["w_down"][top_e]
    up = jnp.einsum("td,tkdf->tkf", x2d, wu)
    if cfg.ffn_kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", x2d, wg)) * up
    else:
        h = jax.nn.gelu(up)
    yk = jnp.einsum("tkf,tkfd->tkd", h, wd)
    y = jnp.einsum("tkd,tk->td", yk, top_p.astype(yk.dtype))
    y = y.reshape(b, s, d)
    if "shared" in params:
        y = y + dense_ffn(params["shared"], x, cfg.ffn_kind)
    if "residual" in params:
        y = y + dense_ffn(params["residual"], x, cfg.ffn_kind)
    return y
