"""Activation-sharding helpers usable inside model code.

Model code calls :func:`constrain` with *logical* axes; if no mesh is active
(CPU smoke tests) the call is a no-op, so the same model runs unsharded on
one device and sharded under ``jax.set_mesh`` in the dry-run/launcher.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# logical axis names
BATCH = "batch"      # maps to ("pod", "data") when a pod axis exists
MODEL = "model"
NONE = None


def _current_axis_names() -> tuple[str, ...]:
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    return tuple(mesh.axis_names)


def resolve(axis: str | None):
    """Map a logical axis to the current mesh's physical axes."""
    names = _current_axis_names()
    if not names or axis is None:
        return None
    if axis == BATCH:
        batch_axes = tuple(n for n in ("pod", "data") if n in names)
        return batch_axes if batch_axes else None
    return axis if axis in names else None


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    names = _current_axis_names()
    if not names:
        return x
    spec = P(*(resolve(a) for a in logical_axes))
    return jax.lax.with_sharding_constraint(x, spec)


def axis_size(axis: str) -> int:
    """Size of a (logical) mesh axis; 1 if absent/no mesh."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    if axis == BATCH:
        return int(
            __import__("math").prod(
                mesh.shape[n] for n in ("pod", "data")
                if n in mesh.axis_names))
    return int(mesh.shape[axis]) if axis in mesh.axis_names else 1
