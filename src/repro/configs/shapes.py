"""Assigned input shapes and the per-(arch x shape) applicability matrix.

Four shapes per architecture:
    train_4k     seq_len=4096    global_batch=256   (training)
    prefill_32k  seq_len=32768   global_batch=32    (inference prefill)
    decode_32k   seq_len=32768   global_batch=128   (one-token decode
                                                     against a 32k cache)
    long_500k    seq_len=524288  global_batch=1     (long-context decode)

``decode_*``/``long_*`` lower ``serve_step`` (one new token with a KV cache
of seq_len), not ``train_step``.  ``long_500k`` requires sub-quadratic
attention: it runs on the SSM/hybrid archs only (skips recorded here and in
DESIGN.md / EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# long_500k runs only on sub-quadratic (SSM / hybrid) stacks.
LONG_CONTEXT_ARCHS = {"xlstm-350m", "jamba-v0.1-52b"}

SKIP_REASONS = {
    "long_500k": ("pure full-attention stack: 500k-token cell requires "
                  "sub-quadratic attention (see DESIGN.md §4)"),
}


def cell_is_runnable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    """(runnable?, reason-if-skipped) for one (arch x shape) cell."""
    if shape_name == "long_500k" and arch_id not in LONG_CONTEXT_ARCHS:
        return False, SKIP_REASONS["long_500k"]
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    from .registry import ARCH_IDS
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
