"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  Backbone only:
the vision tower is a stub — ``input_specs()`` provides precomputed patch
embeddings plus (3, B, S) t/h/w position streams for M-RoPE
(sections 16/24/24 over the 64 half-dim frequencies).
"""

from repro.models.common import ModelConfig

ARCH_ID = "qwen2-vl-2b"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        head_dim=128,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        ffn_kind="swiglu",
        frontend_stub=True,
        block_pattern=("attn",),
    )
