"""arctic-480b — dense-MoE hybrid: 128 experts top-2 + dense residual FFN
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
The dense residual branch runs in parallel with the routed experts.
"""

from repro.models.common import ModelConfig, MoEConfig

ARCH_ID = "arctic-480b"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        rope_theta=1e6,
        ffn_kind="swiglu",
        moe=MoEConfig(
            num_experts=128,
            top_k=2,
            d_ff_expert=4864,
            dense_residual=True,
        ),
        block_pattern=("attn",),
    )
