"""deepseek-v2-236b — MLA attention + fine-grained MoE [arXiv:2405.04434].

60L d_model=5120 128H d_ff=1536 vocab=102400, MoE 160e top-6, 2 shared
experts, MLA kv_lora=512 (qk_nope=128, qk_rope=64, v=128, q_lora=1536).
First layer is dense.
"""

from repro.models.common import ModelConfig, MoEConfig

ARCH_ID = "deepseek-v2-236b"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=1536,
        vocab_size=102400,
        attention_kind="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        ffn_kind="swiglu",
        first_k_dense=1,
        moe=MoEConfig(
            num_experts=160,
            top_k=6,
            d_ff_expert=1536,
            num_shared_experts=2,
        ),
        block_pattern=("attn",),
    )
