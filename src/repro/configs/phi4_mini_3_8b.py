"""phi4-mini-3.8b — dense GQA transformer [arXiv:2412.08905; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 — RoPE SwiGLU GQA.
"""

from repro.models.common import ModelConfig

ARCH_ID = "phi4-mini-3.8b"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=200064,
        rope_theta=10000.0,
        ffn_kind="swiglu",
        block_pattern=("attn",),
    )
