"""qwen2-0.5b — dense GQA transformer with QKV bias [arXiv:2407.10671; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""

from repro.models.common import ModelConfig

ARCH_ID = "qwen2-0.5b"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6,
        ffn_kind="swiglu",
        block_pattern=("attn",),
    )
