"""Architecture registry: ``--arch <id>`` resolution for all entry points."""

from __future__ import annotations

from typing import Callable

from repro.models.common import ModelConfig

from . import (arctic_480b, codeqwen1_5_7b, deepseek_v2_236b, gemma2_2b,
               jamba_v0_1_52b, musicgen_large, phi4_mini_3_8b, qwen2_0_5b,
               qwen2_vl_2b, xlstm_350m)

_MODULES = (
    phi4_mini_3_8b,
    qwen2_0_5b,
    codeqwen1_5_7b,
    gemma2_2b,
    arctic_480b,
    deepseek_v2_236b,
    xlstm_350m,
    musicgen_large,
    jamba_v0_1_52b,
    qwen2_vl_2b,
)

BUILDERS: dict[str, Callable[[], ModelConfig]] = {
    m.ARCH_ID: m.build for m in _MODULES
}
ARCH_IDS: tuple[str, ...] = tuple(BUILDERS)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in BUILDERS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCH_IDS)}")
    return BUILDERS[arch_id]()


def get_reduced_config(arch_id: str, **overrides) -> ModelConfig:
    return get_config(arch_id).reduced(**overrides)
