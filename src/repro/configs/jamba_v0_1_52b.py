"""jamba-v0.1-52b — Mamba + attention 1:7 interleave with MoE
[arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2 on
every 2nd layer.  8-layer super-block: attention at offset 4, Mamba
elsewhere.  Hybrid: the ``long_500k`` cell runs here (Mamba state is O(1);
the 4 attention layers keep a seq-sharded KV cache).
"""

from repro.models.common import ModelConfig, MoEConfig

ARCH_ID = "jamba-v0.1-52b"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        ffn_kind="swiglu",
        use_rope=False,          # Jamba uses no positional encoding
        moe=MoEConfig(
            num_experts=16,
            top_k=2,
            d_ff_expert=14336,
            every_k_layers=2,
        ),
        ssm_state_dim=16,
        ssm_conv_width=4,
        ssm_expand=2,
        block_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
    )
