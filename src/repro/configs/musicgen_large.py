"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (MHA) d_ff=8192 vocab=2048.  Backbone only: the
EnCodec frontend is a stub — ``input_specs()`` provides precomputed frame
embeddings (B, S, d_model).  Sinusoidal absolute positions (no RoPE),
GELU FFN.
"""

from repro.models.common import ModelConfig

ARCH_ID = "musicgen-large"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        use_rope=False,
        ffn_kind="gelu",
        frontend_stub=True,
        block_pattern=("attn",),
    )
