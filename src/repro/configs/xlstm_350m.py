"""xlstm-350m — sLSTM + mLSTM recurrent blocks [arXiv:2405.04517].

24L d_model=1024 4H d_ff=0 vocab=50304.  Blocks alternate mLSTM (parallel
matrix-memory) and sLSTM (sequential scalar-memory); no external FFN
(projections live inside the blocks).  Attention-free: the ``long_500k``
cell runs on this arch (O(1) decode state).
"""

from repro.models.common import ModelConfig

ARCH_ID = "xlstm-350m"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        tie_embeddings=True,
        block_pattern=("mlstm", "slstm"),
    )
