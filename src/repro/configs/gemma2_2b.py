"""gemma2-2b — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim=256,
sliding window 4096 on local layers, attn softcap 50, final softcap 30,
pre+post block norms, scaled + tied embeddings.
"""

from repro.models.common import ModelConfig

ARCH_ID = "gemma2-2b"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        d_ff=9216,
        vocab_size=256000,
        head_dim=256,
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        post_block_norm=True,
        scale_embeddings=True,
        tie_embeddings=True,
        ffn_kind="swiglu",
        block_pattern=("attn_local", "attn_global"),
    )
