"""Assigned architecture configs + input shapes."""

from .registry import ARCH_IDS, BUILDERS, get_config, get_reduced_config  # noqa: F401
from .shapes import SHAPES, InputShape, cell_is_runnable  # noqa: F401
