"""codeqwen1.5-7b — dense MHA (kv=32) [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416 — qwen1.5 arch.
"""

from repro.models.common import ModelConfig

ARCH_ID = "codeqwen1.5-7b"


def build() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        rope_theta=1e6,
        ffn_kind="swiglu",
        block_pattern=("attn",),
    )
