"""Worker-pool scale-out: service throughput scaling + ticket latency.

    PYTHONPATH=src python -m benchmarks.service_bench

Measures the sweep service's horizontal scale-out path
(`SweepService(workers=N)` dispatching onto the chunk-range lease
board of `repro.runtime.workers`) at 10^7 configurations for 1 / 2 / 4
worker processes:

* aggregate throughput (`configs_per_s`) of one large pooled job per
  worker count, plus the wall-clock speedup of 4 workers over 1;
* submit-to-result ticket latency (p50 / p95) under 8 concurrent
  tenants, each submitting its own ~10^5-config job through the
  multi-tenant admission queue;
* the exactness anchor: every pooled fold must be bitwise-identical
  to a solo in-process `stream_grid` run of the same grid — scaling
  out must change *nothing* but the wall clock.

Scaling is physical, so the snapshot records ``host_cores``: the
``speedup_4v1 >= 2.5`` gate is asserted only when the host actually
has >= 4 cores to scale onto (a single-core container runs the same
benchmark honestly and records ~1x).  Bitwise parity is asserted
unconditionally.  Emits ``name,value,derived`` rows and snapshots
``BENCH_service.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import pathlib
import threading
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_service.json"

from benchmarks.stream_bench import _grid_for  # noqa: E402

#: The scaling workload (~10^7 configs, the stream-bench grid).
N_BIG = 10_000_000
WORKER_COUNTS = (1, 2, 4)
N_TENANTS = 8
#: Gate: 4 workers must beat 1 worker by this factor on hosts with
#: enough cores for the ratio to be physical.
MIN_SPEEDUP_4V1 = 2.5


def _bitwise_equal(res, ref) -> bool:
    return (res.min_val == ref.min_val
            and res.min_idx == ref.min_idx
            and res.finite_counts == ref.finite_counts
            and np.array_equal(res.topk_idx, ref.topk_idx)
            and np.array_equal(res.topk_val, ref.topk_val)
            and np.array_equal(res.front_indices, ref.front_indices)
            and np.array_equal(res.front_values, ref.front_values))


def _tenant_latencies(svc, grid: dict) -> dict:
    """Submit one distinct ~10^5-config job per tenant from 8 threads
    at once; return p50/p95 submit-to-result seconds."""
    from repro.core.service import SweepRequest

    lat = [0.0] * N_TENANTS
    errs: list = []

    def one(i: int) -> None:
        # A private fps point per tenant: 8 distinct jobs, no fusion
        # or dedupe shortcuts — each rides the pool on its own.
        g = dict(grid, keynet_fps=(15.0 + i, 30.0))
        t0 = time.perf_counter()
        try:
            svc.submit(SweepRequest(grid=g, tenant=f"tenant-{i}"),
                       ).result(timeout=3600)
        except BaseException as e:          # pragma: no cover
            errs.append(e)
        lat[i] = time.perf_counter() - t0

    threads = [threading.Thread(target=one, args=(i,))
               for i in range(N_TENANTS)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    if errs:
        raise errs[0]
    return {"ticket_p50_s": round(float(np.percentile(lat, 50)), 3),
            "ticket_p95_s": round(float(np.percentile(lat, 95)), 3)}


def rows():
    from repro.core import stream
    from repro.core.service import SweepRequest, SweepService

    host_cores = os.cpu_count() or 1
    big_grid = _grid_for(N_BIG)
    small_grid = _grid_for(100_000)
    warm_grid = _grid_for(0)                # the 10,880-config reference

    # The exactness anchor: one solo in-process run of the big grid.
    ref = stream.stream_grid(**big_grid, track="all")
    n_big = int(ref.n_configs)

    out = []
    per_worker: dict = {}
    bitwise_all = True
    for w in WORKER_COUNTS:
        svc = SweepService(capacity=64, snapshot_every_s=0.0, workers=w)
        try:
            # Warm the pool: the worker processes import jax and run a
            # small job before the timed one, so process startup is not
            # billed to the scaling curve.
            svc.submit(SweepRequest(grid=warm_grid,
                                    track="all")).result(timeout=3600)
            t0 = time.perf_counter()
            res = svc.submit(SweepRequest(grid=big_grid,
                                          track="all")).result(
                                              timeout=3600)
            wall = time.perf_counter() - t0
            ok = _bitwise_equal(res, ref)
            bitwise_all = bitwise_all and ok
            assert svc.counters["pooled_executions"] >= 2, svc.counters
            per_worker[str(w)] = {
                "wall_s": round(wall, 2),
                "configs_per_s": round(n_big / wall, 1),
                "n_parts": int(res.stats["n_parts"]),
                "leases_reissued": int(svc.counters["leases_reissued"]),
                "bitwise_identical": bool(ok),
            }
            per_worker[str(w)].update(
                _tenant_latencies(svc, small_grid))
        finally:
            svc.close()
        pw = per_worker[str(w)]
        out.append((f"service.w{w}.configs_per_s",
                    pw["configs_per_s"],
                    f"{w}-worker pool over {n_big} configs, "
                    f"{pw['n_parts']} leases folded"))
        out.append((f"service.w{w}.ticket_p50_s", pw["ticket_p50_s"],
                    f"{N_TENANTS} concurrent tenants, ~1e5 configs "
                    f"each"))
        out.append((f"service.w{w}.ticket_p95_s", pw["ticket_p95_s"],
                    "tail of the same tenant burst"))

    assert bitwise_all, \
        "a pooled fold diverged from the solo run — scale-out broke " \
        "exactness"

    speedup = (per_worker["1"]["wall_s"] / per_worker["4"]["wall_s"])
    gated = host_cores >= max(WORKER_COUNTS)
    if gated:
        assert speedup >= MIN_SPEEDUP_4V1, (
            f"4-worker speedup {speedup:.2f}x < {MIN_SPEEDUP_4V1}x on a "
            f"{host_cores}-core host")
    out.append(("service.speedup_4v1", round(speedup, 3),
                (f"gated >= {MIN_SPEEDUP_4V1}x ({host_cores} cores)"
                 if gated else
                 f"informational: only {host_cores} host core(s), "
                 f"scaling is not physical here")))
    out.append(("service.bitwise_identical", 1.0,
                "every pooled fold == solo run, all worker counts"))

    snapshot = {
        "bench": "service_scaleout",
        "n_configs": n_big,
        "host_cores": host_cores,
        "tenants": N_TENANTS,
        "workers": per_worker,
        "speedup_4v1": round(speedup, 3),
        "speedup_gate": MIN_SPEEDUP_4V1,
        "speedup_gated": gated,
        "bitwise_identical": bitwise_all,
    }
    BENCH_JSON.write_text(json.dumps(snapshot, indent=2) + "\n")
    return out


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")
    print(f"(snapshot written to {BENCH_JSON})")
