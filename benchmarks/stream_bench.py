"""Streaming vs dense design-space sweeps: throughput and peak memory.

    PYTHONPATH=src python -m benchmarks.stream_bench

Measures the streaming executor (`repro.core.stream.stream_grid`) against
the dense grid engine (`repro.core.sweep.evaluate_grid`) at 10^5 / 10^6 /
10^7 configurations.  Both modes are timed to the *same deliverables* —
per-objective argmin, top-k, channel bounds, feasibility counts, and the
exact Pareto front (everything a `StreamResult` always carries; the
dense worker runs the equivalent `SweepResult`/`pareto` calls) — so
`configs_per_s` compares completing the same sweep analysis.  The dense
worker additionally reports `eval_configs_per_s` (evaluation only, the
PR-1/PR-3 comparable number).  The stream worker runs sharded across
CPU cores (its deployment configuration); each measurement runs in its
own subprocess so peak RSS is attributable per (mode, size) — dense
memory grows O(grid) (unrunnable at 10^7 on small hosts) while
streaming stays flat at O(chunk + front).  Exact argmin/top-k/
Pareto-front parity on the 10,880-config reference grid is asserted and
recorded.

Scan-fused dispatch (``stream_grid(scan_chunks=)``, the backend layer's
``lax.scan`` over K chunk carries per device dispatch) is measured at
10^7 and a streaming-only 10^8-config point: per-chunk ``dispatch_s``
and ``steps_per_s`` are recorded alongside the merge-stall fields, with
the forced ``scan_chunks=1`` per-chunk baseline for the overhead ratio.

Checkpoint overhead (``stream_grid(checkpoint_dir=)``, the fault-
tolerance tentpole's durable snapshots) is measured at 10^7 against the
bare streaming run: the default 30 s interval (target < 2% throughput
loss) and a 1 s worst case, each into a fresh directory per repetition
so nothing resumes.  Emits ``name,value,derived`` rows and snapshots
``BENCH_stream.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import pathlib
import resource
import subprocess
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_stream.json"
SRC = pathlib.Path(__file__).resolve().parent.parent / "src"

#: The PR-1 reference grid (10,880 configs) — the exact-parity anchor,
#: shared with the dense-engine benchmark so the two suites can never
#: drift onto different grids.
from benchmarks.sweep_bench import GRID as REFERENCE_GRID  # noqa: E402


def _grid_for(n: int) -> dict:
    """Reference grid widened along the rate axes to ~n configurations."""
    g = dict(REFERENCE_GRID)
    if n >= 100_000_000:
        g["detnet_fps"] = tuple(np.linspace(5.0, 30.0, 50))
        g["keynet_fps"] = tuple(np.linspace(15.0, 30.0, 20))
        g["camera_fps"] = tuple(np.linspace(20.0, 60.0, 92))   # 100,096,000
    elif n >= 10_000_000:
        g["detnet_fps"] = tuple(np.linspace(5.0, 30.0, 50))
        g["camera_fps"] = tuple(np.linspace(20.0, 60.0, 92))   # 10,009,600
    elif n >= 1_000_000:
        g["camera_fps"] = tuple(np.linspace(20.0, 60.0, 92))   # 1,000,960
    elif n >= 100_000:
        g["camera_fps"] = tuple(np.linspace(20.0, 60.0, 9))    # 97,920
    return g


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _mem_available_mb() -> float:
    try:
        with open("/proc/meminfo") as fh:
            for line in fh:
                if line.startswith("MemAvailable"):
                    return float(line.split()[1]) / 1024.0
    except OSError:
        pass
    return float("inf")


def _worker(mode: str, n: int, scan: int | None = None,
            ckpt_every_s: float | None = None) -> dict:
    from repro.core import stream, sweep

    grid = _grid_for(n)
    # Short runs are scheduler/frequency-noise dominated on small hosts:
    # take the best of more repetitions there (runs at these sizes are
    # tens of ms, so the extra reps are free next to the jit compile).
    reps = 8 if n <= 1_000_000 else (3 if n <= 10_000_000 else 1)
    if mode == "dense":
        import numpy as np

        from repro.core import pareto

        # 11 host channel grids + their device twins, minus what XLA
        # frees early — the meshgrid coordinate arrays are gone (the
        # dense engine decodes flat indices on device now), but the
        # gathered per-lane kernel inputs still exist transiently.
        need_mb = n * 8 * 21 / 2**20 * 1.5
        if need_mb > _mem_available_mb():
            return {"mode": mode, "n": n, "skipped":
                    f"needs ~{need_mb:.0f} MB dense grid memory, "
                    f"{_mem_available_mb():.0f} MB available"}
        res = sweep.evaluate_grid(**grid)          # compile + first run
        best = None
        for _ in range(reps):                      # post-compile, best-of
            t0 = time.perf_counter()
            res = sweep.evaluate_grid(**grid)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        # The headline configs_per_s covers the *same deliverables* a
        # StreamResult always carries — per-objective argmin, top-k,
        # bounds, feasibility counts, and the exact Pareto front — so
        # the two modes are compared on completing the same sweep
        # analysis.  eval_configs_per_s keeps the PR-1/PR-3 comparable
        # evaluation-only number in the trail.
        t0 = time.perf_counter()
        for o in pareto.DEFAULT_OBJECTIVES:
            res.argmin(o)
            res.top_k(o, 4)
            res.channel_bounds(o)
            int(np.isfinite(res.data[o]).sum())
        front = pareto.pareto_front(res)
        t_analysis = time.perf_counter() - t0
        return {"mode": mode, "n": res.n_configs,
                "configs_per_s": round(res.n_configs
                                       / (best + t_analysis), 1),
                "eval_configs_per_s": round(res.n_configs / best, 1),
                "analysis_s": round(t_analysis, 4),
                "front_size": int(front.size),
                "peak_rss_mb": round(_rss_mb(), 1),
                "best_power_mw": round(res.argmin()["avg_power"] * 1e3, 4)}
    kw = dict(grid)
    if scan is not None:
        kw["scan_chunks"] = scan
    res = stream.stream_grid(**kw)                 # compile + first run
    best_stats = None
    for _ in range(reps):                          # post-compile, best-of
        if ckpt_every_s is not None:
            # Fresh directory per repetition: a reused one would resume
            # from its own terminal snapshot and measure nothing.
            import shutil
            import tempfile
            ckpt_dir = tempfile.mkdtemp(prefix="stream_bench_ckpt_")
            try:
                res = stream.stream_grid(
                    **kw, checkpoint_dir=ckpt_dir,
                    checkpoint_every_s=ckpt_every_s)
            finally:
                shutil.rmtree(ckpt_dir, ignore_errors=True)
        else:
            res = stream.stream_grid(**kw)
        if (best_stats is None
                or res.stats["total_s"] < best_stats["total_s"]):
            best_stats = res.stats
    return {"mode": mode, "n": res.n_configs,
            "checkpoints_written":
                int(best_stats.get("checkpoints_written", 0)),
            "checkpoint_write_s":
                round(best_stats.get("checkpoint_write_s", 0.0), 4),
            "configs_per_s": round(res.n_configs
                                   / best_stats["total_s"], 1),
            "steady_configs_per_s":
                round(best_stats["steady_configs_per_s"], 1),
            "peak_rss_mb": round(_rss_mb(), 1),
            "front_size": int(res.front_indices.size),
            # Pipeline accounting: host-merge seconds (exact front/merge
            # work on the host) vs the time the host spent stalled on
            # device results — the overlap the async pipeline buys.
            "host_merge_s": round(best_stats["host_merge_s"], 4),
            "device_wait_s": round(best_stats["device_wait_s"], 4),
            # Dispatch accounting: time spent invoking the compiled
            # step (post-warmup: pure per-step overhead) and dispatches
            # per second — scan fusion's target quantities.
            "dispatch_s": round(best_stats["dispatch_s"], 4),
            "steps_per_s": round(best_stats["steps_per_s"], 2),
            "n_steps": int(best_stats["n_chunks"]),
            "scan_chunks": int(best_stats["scan_chunks"]),
            "best_power_mw": round(res.argmin()["avg_power"] * 1e3, 4)}


def _spawn(mode: str, n: int, scan: int | None = None,
           ckpt_every_s: float | None = None) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(SRC)] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                      if p])
    if mode == "stream":
        # The streaming executor's deployment mode on CPU hosts: shard
        # the chunk stream across one XLA host device per core (the
        # executor's pmap path picks them up automatically).  A single
        # XLA CPU device leaves the fused reduction step effectively
        # single-threaded (~2x slower on this 2-core reference box);
        # the dense path has no sharded execution mode, so it runs in
        # its own best (default single-device) configuration.
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count="
                            + str(os.cpu_count() or 1))
    cmd = [sys.executable, "-m", "benchmarks.stream_bench", "--worker",
           mode, str(n)]
    if scan is not None or ckpt_every_s is not None:
        cmd.append("-" if scan is None else str(scan))
    if ckpt_every_s is not None:
        cmd.append(str(ckpt_every_s))
    out = subprocess.run(
        cmd, capture_output=True, text=True, timeout=3600,
        cwd=str(SRC.parent), env=env)
    if out.returncode != 0:
        return {"mode": mode, "n": n,
                "failed": out.stderr.strip().splitlines()[-1]
                if out.stderr.strip() else "worker died"}
    return json.loads(out.stdout.strip().splitlines()[-1])


def _parity() -> dict:
    """Exact stream/dense agreement on the 10,880 reference grid."""
    from repro.core import pareto, stream, sweep

    dense = sweep.evaluate_grid(**REFERENCE_GRID)
    res = stream.stream_grid(**REFERENCE_GRID, chunk_size=4096,
                             track="all")
    df, sf = pareto.pareto_front(dense), res.pareto_front()
    return {
        "grid_configs": dense.n_configs,
        "argmin": all(res.argmin(f) == dense.argmin(f)
                      for f in sweep.FIELDS),
        "top_k": all(res.top_k(o) == dense.top_k(o, 4)
                     for o in res.objectives),
        "pareto_front": bool(np.array_equal(df.indices, sf.indices)
                             and np.array_equal(df.values, sf.values)),
    }


def rows():
    parity = _parity()
    assert all(parity[k] for k in ("argmin", "top_k", "pareto_front")), \
        f"stream/dense parity violated: {parity}"

    def median_worker(results):
        ok = [r for r in results if "configs_per_s" in r]
        if not ok:
            return results[-1]
        ok.sort(key=lambda r: r["configs_per_s"])
        return ok[len(ok) // 2]

    points = []
    out = []
    for n in (100_000, 1_000_000, 10_000_000):
        # Adjacent (stream, dense) runs so shared-host noise hits both.
        # The short sizes are frequency/scheduler-noise dominated on a
        # small host (worker-to-worker spread up to ~3x, either
        # direction), so they run three alternating pairs and each mode
        # reports its *median* worker — a single best-of would let one
        # boost window decide the ratio.
        pairs = 3 if n <= 1_000_000 else 1
        s_runs, d_runs = [], []
        for _ in range(pairs):
            s_runs.append(_spawn("stream", n))
            d_runs.append(_spawn("dense", n))
        s, d = median_worker(s_runs), median_worker(d_runs)
        points.append({"n": n, "stream": s, "dense": d})
        tag = f"{n:.0e}".replace("+0", "").replace("+", "")
        if "configs_per_s" in s:
            out.append((f"stream.{tag}.configs_per_s",
                        s["configs_per_s"],
                        f"steady {s.get('steady_configs_per_s', 0):.3g}/s "
                        f"rss {s['peak_rss_mb']:.0f}MB "
                        f"front {s.get('front_size', 0)} "
                        f"merge-stall {s.get('host_merge_s', 0):.3f}s"))
        else:
            out.append((f"stream.{tag}.FAILED", 0.0, str(s)))
        if "configs_per_s" in d:
            out.append((f"dense.{tag}.configs_per_s", d["configs_per_s"],
                        f"eval-only {d.get('eval_configs_per_s', 0):.3g}/s"
                        f" analysis {d.get('analysis_s', 0):.3f}s "
                        f"rss {d['peak_rss_mb']:.0f}MB"))
        else:
            out.append((f"dense.{tag}.skipped", 0.0,
                        d.get("skipped", d.get("failed", "?"))))

    # Scan-fused dispatch: stream-only points comparing auto-fused
    # (scan_chunks chosen from the step count) against forced per-chunk
    # dispatch (scan_chunks=1) at 10^7 and 10^8 configs — the dense
    # path cannot run 10^8 (the full channel grids alone are ~9 GB).
    # Each chunk's share of the per-dispatch fixed cost falls K-fold,
    # so the robust signal is the *dispatch count* (and dispatch_s per
    # step); note XLA CPU dispatch is synchronous, so dispatch_s also
    # absorbs blocked device compute — on accelerator backends it
    # isolates the launch overhead scan fusion amortizes.  1e7 runs are
    # noise-dominated on small hosts: alternate pairs, report medians.
    scan_fused = {}
    for n, tag, pairs, k_fused in ((10_000_000, "1e7", 2, 4),
                                   (100_000_000, "1e8", 1, 8)):
        # Explicit K for the fused arm: auto-K depends on the per-device
        # step count, so on many-core hosts it could resolve to 1 and
        # this comparison would silently measure nothing.
        f_runs, p_runs = [], []
        for _ in range(pairs):
            f_runs.append(_spawn("stream", n, scan=k_fused))
            p_runs.append(_spawn("stream", n, scan=1))
        fused = median_worker(f_runs)
        per_chunk = median_worker(p_runs)
        scan_fused[tag] = {"fused": fused, "per_chunk": per_chunk}
        if "configs_per_s" not in fused or "configs_per_s" not in per_chunk:
            out.append((f"stream.{tag}.scan_fused.FAILED", 0.0,
                        str(fused if 'configs_per_s' not in fused
                            else per_chunk)))
            continue
        out.append((
            f"stream.{tag}.scan_fused_configs_per_s",
            fused["configs_per_s"],
            f"K={fused.get('scan_chunks')} "
            f"{fused.get('n_steps')} dispatches "
            f"(vs {per_chunk.get('n_steps')} per-chunk) "
            f"rss {fused.get('peak_rss_mb', 0):.0f}MB"))
        out.append((
            f"stream.{tag}.dispatches_cut",
            round(per_chunk["n_steps"] / max(fused["n_steps"], 1), 2),
            f"per-chunk {per_chunk['n_steps']} dispatches "
            f"({per_chunk['dispatch_s']:.2f}s in-call) -> fused "
            f"{fused['n_steps']} ({fused['dispatch_s']:.2f}s); "
            f"throughput {fused['configs_per_s'] / per_chunk['configs_per_s']:.2f}x"))

    # Checkpoint overhead at 1e7: the fault-tolerance tentpole's cost
    # target is < 2% throughput loss at the default interval (30 s —
    # at this size that is the terminal snapshot plus at most a handful
    # of periodic ones).  The 1 s-interval row bounds the worst case
    # (a checkpoint nearly every macro step).  Single 1e7 runs carry a
    # few percent of shared-host noise, so the default-interval ratio
    # can read slightly negative; the write-time accounting
    # (checkpoint_write_s) is the noise-free number.
    base_1e7 = next(p for p in points if p["n"] == 10_000_000)["stream"]
    checkpoint_overhead = {"baseline": base_1e7}
    for tag, every_s in (("default", 30.0), ("1s", 1.0)):
        r = _spawn("stream", 10_000_000, ckpt_every_s=every_s)
        checkpoint_overhead[tag] = r
        if "configs_per_s" not in r or "configs_per_s" not in base_1e7:
            out.append((f"stream.1e7.ckpt_{tag}.FAILED", 0.0, str(r)))
            continue
        pct = 100.0 * (1.0 - r["configs_per_s"]
                       / base_1e7["configs_per_s"])
        checkpoint_overhead[f"overhead_pct_{tag}"] = round(pct, 2)
        out.append((
            f"stream.1e7.ckpt_{tag}.overhead_pct", round(pct, 2),
            f"every {every_s:g}s: {r['checkpoints_written']} snapshots, "
            f"{r['checkpoint_write_s']:.3f}s writing "
            f"({r['configs_per_s']:.3g}/s vs "
            f"{base_1e7['configs_per_s']:.3g}/s bare; target < 2% "
            f"at default interval)"))

    def ratio_at(n):
        p = next((p for p in points if p["n"] == n), None)
        if (p and "configs_per_s" in p["stream"]
                and "configs_per_s" in p["dense"]):
            return round(p["stream"]["configs_per_s"]
                         / p["dense"]["configs_per_s"], 2)
        return None

    s_small = points[0]["stream"].get("peak_rss_mb")
    s_big = points[-1]["stream"].get("peak_rss_mb")
    snapshot = {
        "parity_10880": parity,
        "points": points,
        # Per-chunk dispatch overhead vs lax.scan-fused multi-chunk
        # dispatch (exact parity preserved; see tests/test_backend.py).
        "scan_fused": scan_fused,
        # Fault-tolerance tentpole: durable checkpoint cost at 1e7
        # (default 30 s interval vs a 1 s worst case).
        "checkpoint_overhead_1e7": checkpoint_overhead,
        "stream_rss_growth_1e5_to_1e7":
            (round(s_big / s_small, 2) if s_small and s_big else None),
        # The regression PR 4 fixed (fused on-device reductions + async
        # double-buffered streaming) stays visible here: streaming must
        # hold >= 1.0 at every size, most critically at 1e5 where PR 3
        # recorded 0.37.  Per-point host_merge_s / device_wait_s above
        # record the merge-stall accounting behind it.
        "stream_vs_dense_at_1e5": ratio_at(100_000),
        "stream_vs_dense_at_1e6": ratio_at(1_000_000),
        "stream_vs_dense_at_1e7": ratio_at(10_000_000),
        "pr3_stream_vs_dense": {"1e5": 0.37, "1e6": 0.51, "1e7": 1.13},
        "pr1_dense_baseline_configs_per_s": 1_662_391.5,
    }
    BENCH_JSON.write_text(json.dumps(snapshot, indent=2) + "\n")

    out.append(("stream.parity_10880",
                1.0, "argmin/top-k/front exactly equal dense"))
    for n in (100_000, 1_000_000, 10_000_000):
        r = ratio_at(n)
        if r is not None:
            out.append((f"stream.vs_dense_{n:.0e}".replace("+0", ""),
                        r, "streaming/dense throughput ratio (>= 1.0)"))
    if s_small and s_big:
        out.append(("stream.rss_growth_1e5_to_1e7", s_big / s_small,
                    "bounded host memory: peak RSS ratio across 100x grid"))
    return out


def main() -> None:
    if len(sys.argv) >= 4 and sys.argv[1] == "--worker":
        scan = None
        if len(sys.argv) >= 5 and sys.argv[4] != "-":
            scan = int(sys.argv[4])
        ckpt = float(sys.argv[5]) if len(sys.argv) >= 6 else None
        print(json.dumps(_worker(sys.argv[2], int(sys.argv[3]), scan,
                                 ckpt)))
        return
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")
    print(f"(snapshot written to {BENCH_JSON})")


if __name__ == "__main__":
    main()
