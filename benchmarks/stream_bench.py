"""Streaming vs dense design-space sweeps: throughput and peak memory.

    PYTHONPATH=src python -m benchmarks.stream_bench

Measures the streaming executor (`repro.core.stream.stream_grid`) against
the dense grid engine (`repro.core.sweep.evaluate_grid`) at 10^5 / 10^6 /
10^7 configurations.  Both modes are timed to the *same deliverables* —
per-objective argmin, top-k, channel bounds, feasibility counts, and the
exact Pareto front (everything a `StreamResult` always carries; the
dense worker runs the equivalent `SweepResult`/`pareto` calls) — so
`configs_per_s` compares completing the same sweep analysis.  The dense
worker additionally reports `eval_configs_per_s` (evaluation only, the
PR-1/PR-3 comparable number).  The stream worker runs sharded across
CPU cores (its deployment configuration); each measurement runs in its
own subprocess so peak RSS is attributable per (mode, size) — dense
memory grows O(grid) (unrunnable at 10^7 on small hosts) while
streaming stays flat at O(chunk + front).  Exact argmin/top-k/
Pareto-front parity on the 10,880-config reference grid is asserted and
recorded.  Emits ``name,value,derived`` rows and snapshots
``BENCH_stream.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import pathlib
import resource
import subprocess
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_stream.json"
SRC = pathlib.Path(__file__).resolve().parent.parent / "src"

#: The PR-1 reference grid (10,880 configs) — the exact-parity anchor,
#: shared with the dense-engine benchmark so the two suites can never
#: drift onto different grids.
from benchmarks.sweep_bench import GRID as REFERENCE_GRID  # noqa: E402


def _grid_for(n: int) -> dict:
    """Reference grid widened along the rate axes to ~n configurations."""
    g = dict(REFERENCE_GRID)
    if n >= 10_000_000:
        g["detnet_fps"] = tuple(np.linspace(5.0, 30.0, 50))
        g["camera_fps"] = tuple(np.linspace(20.0, 60.0, 92))   # 10,009,600
    elif n >= 1_000_000:
        g["camera_fps"] = tuple(np.linspace(20.0, 60.0, 92))   # 1,000,960
    elif n >= 100_000:
        g["camera_fps"] = tuple(np.linspace(20.0, 60.0, 9))    # 97,920
    return g


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _mem_available_mb() -> float:
    try:
        with open("/proc/meminfo") as fh:
            for line in fh:
                if line.startswith("MemAvailable"):
                    return float(line.split()[1]) / 1024.0
    except OSError:
        pass
    return float("inf")


def _worker(mode: str, n: int) -> dict:
    from repro.core import stream, sweep

    grid = _grid_for(n)
    # Short runs are scheduler/frequency-noise dominated on small hosts:
    # take the best of more repetitions there (runs at these sizes are
    # tens of ms, so the extra reps are free next to the jit compile).
    reps = 8 if n <= 1_000_000 else 3
    if mode == "dense":
        import numpy as np

        from repro.core import pareto

        # 11 channels + 10 meshgrid coordinate arrays, all float64.
        need_mb = n * 8 * 21 / 2**20 * 1.5
        if need_mb > _mem_available_mb():
            return {"mode": mode, "n": n, "skipped":
                    f"needs ~{need_mb:.0f} MB dense grid memory, "
                    f"{_mem_available_mb():.0f} MB available"}
        res = sweep.evaluate_grid(**grid)          # compile + first run
        best = None
        for _ in range(reps):                      # post-compile, best-of
            t0 = time.perf_counter()
            res = sweep.evaluate_grid(**grid)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        # The headline configs_per_s covers the *same deliverables* a
        # StreamResult always carries — per-objective argmin, top-k,
        # bounds, feasibility counts, and the exact Pareto front — so
        # the two modes are compared on completing the same sweep
        # analysis.  eval_configs_per_s keeps the PR-1/PR-3 comparable
        # evaluation-only number in the trail.
        t0 = time.perf_counter()
        for o in pareto.DEFAULT_OBJECTIVES:
            res.argmin(o)
            res.top_k(o, 4)
            res.channel_bounds(o)
            int(np.isfinite(res.data[o]).sum())
        front = pareto.pareto_front(res)
        t_analysis = time.perf_counter() - t0
        return {"mode": mode, "n": res.n_configs,
                "configs_per_s": round(res.n_configs
                                       / (best + t_analysis), 1),
                "eval_configs_per_s": round(res.n_configs / best, 1),
                "analysis_s": round(t_analysis, 4),
                "front_size": int(front.size),
                "peak_rss_mb": round(_rss_mb(), 1),
                "best_power_mw": round(res.argmin()["avg_power"] * 1e3, 4)}
    res = stream.stream_grid(**grid)               # compile + first run
    best_stats = None
    for _ in range(reps):                          # post-compile, best-of
        res = stream.stream_grid(**grid)
        if (best_stats is None
                or res.stats["total_s"] < best_stats["total_s"]):
            best_stats = res.stats
    return {"mode": mode, "n": res.n_configs,
            "configs_per_s": round(res.n_configs
                                   / best_stats["total_s"], 1),
            "steady_configs_per_s":
                round(best_stats["steady_configs_per_s"], 1),
            "peak_rss_mb": round(_rss_mb(), 1),
            "front_size": int(res.front_indices.size),
            # Pipeline accounting: host-merge seconds (exact front/merge
            # work on the host) vs the time the host spent stalled on
            # device results — the overlap the async pipeline buys.
            "host_merge_s": round(best_stats["host_merge_s"], 4),
            "device_wait_s": round(best_stats["device_wait_s"], 4),
            "best_power_mw": round(res.argmin()["avg_power"] * 1e3, 4)}


def _spawn(mode: str, n: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(SRC)] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                      if p])
    if mode == "stream":
        # The streaming executor's deployment mode on CPU hosts: shard
        # the chunk stream across one XLA host device per core (the
        # executor's pmap path picks them up automatically).  A single
        # XLA CPU device leaves the fused reduction step effectively
        # single-threaded (~2x slower on this 2-core reference box);
        # the dense path has no sharded execution mode, so it runs in
        # its own best (default single-device) configuration.
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count="
                            + str(os.cpu_count() or 1))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.stream_bench", "--worker",
         mode, str(n)],
        capture_output=True, text=True, timeout=1800,
        cwd=str(SRC.parent), env=env)
    if out.returncode != 0:
        return {"mode": mode, "n": n,
                "failed": out.stderr.strip().splitlines()[-1]
                if out.stderr.strip() else "worker died"}
    return json.loads(out.stdout.strip().splitlines()[-1])


def _parity() -> dict:
    """Exact stream/dense agreement on the 10,880 reference grid."""
    from repro.core import pareto, stream, sweep

    dense = sweep.evaluate_grid(**REFERENCE_GRID)
    res = stream.stream_grid(**REFERENCE_GRID, chunk_size=4096,
                             track="all")
    df, sf = pareto.pareto_front(dense), res.pareto_front()
    return {
        "grid_configs": dense.n_configs,
        "argmin": all(res.argmin(f) == dense.argmin(f)
                      for f in sweep.FIELDS),
        "top_k": all(res.top_k(o) == dense.top_k(o, 4)
                     for o in res.objectives),
        "pareto_front": bool(np.array_equal(df.indices, sf.indices)
                             and np.array_equal(df.values, sf.values)),
    }


def rows():
    parity = _parity()
    assert all(parity[k] for k in ("argmin", "top_k", "pareto_front")), \
        f"stream/dense parity violated: {parity}"

    def median_worker(results):
        ok = [r for r in results if "configs_per_s" in r]
        if not ok:
            return results[-1]
        ok.sort(key=lambda r: r["configs_per_s"])
        return ok[len(ok) // 2]

    points = []
    out = []
    for n in (100_000, 1_000_000, 10_000_000):
        # Adjacent (stream, dense) runs so shared-host noise hits both.
        # The short sizes are frequency/scheduler-noise dominated on a
        # small host (worker-to-worker spread up to ~3x, either
        # direction), so they run three alternating pairs and each mode
        # reports its *median* worker — a single best-of would let one
        # boost window decide the ratio.
        pairs = 3 if n <= 1_000_000 else 1
        s_runs, d_runs = [], []
        for _ in range(pairs):
            s_runs.append(_spawn("stream", n))
            d_runs.append(_spawn("dense", n))
        s, d = median_worker(s_runs), median_worker(d_runs)
        points.append({"n": n, "stream": s, "dense": d})
        tag = f"{n:.0e}".replace("+0", "").replace("+", "")
        if "configs_per_s" in s:
            out.append((f"stream.{tag}.configs_per_s",
                        s["configs_per_s"],
                        f"steady {s.get('steady_configs_per_s', 0):.3g}/s "
                        f"rss {s['peak_rss_mb']:.0f}MB "
                        f"front {s.get('front_size', 0)} "
                        f"merge-stall {s.get('host_merge_s', 0):.3f}s"))
        else:
            out.append((f"stream.{tag}.FAILED", 0.0, str(s)))
        if "configs_per_s" in d:
            out.append((f"dense.{tag}.configs_per_s", d["configs_per_s"],
                        f"eval-only {d.get('eval_configs_per_s', 0):.3g}/s"
                        f" analysis {d.get('analysis_s', 0):.3f}s "
                        f"rss {d['peak_rss_mb']:.0f}MB"))
        else:
            out.append((f"dense.{tag}.skipped", 0.0,
                        d.get("skipped", d.get("failed", "?"))))

    def ratio_at(n):
        p = next((p for p in points if p["n"] == n), None)
        if (p and "configs_per_s" in p["stream"]
                and "configs_per_s" in p["dense"]):
            return round(p["stream"]["configs_per_s"]
                         / p["dense"]["configs_per_s"], 2)
        return None

    s_small = points[0]["stream"].get("peak_rss_mb")
    s_big = points[-1]["stream"].get("peak_rss_mb")
    snapshot = {
        "parity_10880": parity,
        "points": points,
        "stream_rss_growth_1e5_to_1e7":
            (round(s_big / s_small, 2) if s_small and s_big else None),
        # The regression PR 4 fixed (fused on-device reductions + async
        # double-buffered streaming) stays visible here: streaming must
        # hold >= 1.0 at every size, most critically at 1e5 where PR 3
        # recorded 0.37.  Per-point host_merge_s / device_wait_s above
        # record the merge-stall accounting behind it.
        "stream_vs_dense_at_1e5": ratio_at(100_000),
        "stream_vs_dense_at_1e6": ratio_at(1_000_000),
        "stream_vs_dense_at_1e7": ratio_at(10_000_000),
        "pr3_stream_vs_dense": {"1e5": 0.37, "1e6": 0.51, "1e7": 1.13},
        "pr1_dense_baseline_configs_per_s": 1_662_391.5,
    }
    BENCH_JSON.write_text(json.dumps(snapshot, indent=2) + "\n")

    out.append(("stream.parity_10880",
                1.0, "argmin/top-k/front exactly equal dense"))
    for n in (100_000, 1_000_000, 10_000_000):
        r = ratio_at(n)
        if r is not None:
            out.append((f"stream.vs_dense_{n:.0e}".replace("+0", ""),
                        r, "streaming/dense throughput ratio (>= 1.0)"))
    if s_small and s_big:
        out.append(("stream.rss_growth_1e5_to_1e7", s_big / s_small,
                    "bounded host memory: peak RSS ratio across 100x grid"))
    return out


def main() -> None:
    if len(sys.argv) >= 4 and sys.argv[1] == "--worker":
        print(json.dumps(_worker(sys.argv[2], int(sys.argv[3]))))
        return
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")
    print(f"(snapshot written to {BENCH_JSON})")


if __name__ == "__main__":
    main()
