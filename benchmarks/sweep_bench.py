"""Design-space sweep throughput: scalar loop vs vectorized grid engine.

    PYTHONPATH=src python -m benchmarks.sweep_bench

Times the same Eq. 1-11 evaluation through both paths on a >=10,000
configuration grid (cut x agg node x sensor node x weight mem x DetNet fps
x KeyNet fps x cameras x MIPI energy scale).  The vectorized number is
post-jit (compile time is reported separately, not counted).  Emits
``name,value,derived`` rows via :func:`rows` and snapshots the result to
``BENCH_sweep.json`` at the repo root so future PRs have a perf
trajectory.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_sweep.json"

# The benchmark grid: 34 cuts x 2 x 2 x 2 x 5 x 2 x 2 x 2 = 10,880 configs.
GRID = dict(
    agg_nodes=("7nm", "16nm"),
    sensor_nodes=("7nm", "16nm"),
    weight_mems=("sram", "mram"),
    detnet_fps=(5.0, 10.0, 15.0, 20.0, 30.0),
    keynet_fps=(15.0, 30.0),
    num_cameras=(2, 4),
    mipi_energy_scale=(1.0, 2.0),
)
SCALAR_SAMPLES = 128   # scalar configs timed (then extrapolated)
VECTOR_REPS = 5        # post-jit timed repetitions of the full grid


def _scalar_configs_per_s(n_cuts: int) -> float:
    """Throughput of the scalar dataclass loop over a grid sample."""
    from repro.core import partition

    rng = np.random.default_rng(0)
    axes = GRID
    picks = []
    for _ in range(SCALAR_SAMPLES):
        picks.append(dict(
            cut=int(rng.integers(0, n_cuts)),
            agg_node=axes["agg_nodes"][rng.integers(2)],
            sensor_node=axes["sensor_nodes"][rng.integers(2)],
            sensor_weight_mem="sram",   # always-valid corner
            detnet_fps=axes["detnet_fps"][rng.integers(5)],
            keynet_fps=axes["keynet_fps"][rng.integers(2)],
            num_cameras=axes["num_cameras"][rng.integers(2)],
            mipi_energy_scale=axes["mipi_energy_scale"][rng.integers(2)],
        ))
    partition.evaluate_cut(0)           # warm the workload caches
    t0 = time.perf_counter()
    for kw in picks:
        partition.evaluate_cut(**kw)
    dt = time.perf_counter() - t0
    return SCALAR_SAMPLES / dt


def rows():
    from repro.core import sweep
    from repro.core.arrays import model_arrays

    n_cuts = model_arrays().n_cuts
    scalar_cps = _scalar_configs_per_s(n_cuts)

    # --- vectorized engine: compile once, then time the steady state ---
    t0 = time.perf_counter()
    res = sweep.evaluate_grid(**GRID)
    compile_s = time.perf_counter() - t0
    n = res.n_configs
    assert n >= 10_000, n
    t0 = time.perf_counter()
    for _ in range(VECTOR_REPS):
        res = sweep.evaluate_grid(**GRID)
    vector_cps = VECTOR_REPS * n / (time.perf_counter() - t0)
    speedup = vector_cps / scalar_cps

    best = res.argmin()
    snapshot = {
        "grid_configs": n,
        "scalar_configs_per_s": round(scalar_cps, 1),
        "vector_configs_per_s": round(vector_cps, 1),
        "speedup": round(speedup, 1),
        "compile_s": round(compile_s, 3),
        "best_config": {k: (int(v) if isinstance(v, (int, np.integer))
                            else float(v) if isinstance(v, (float,
                                                            np.floating))
                            else v) for k, v in best.items()},
    }
    BENCH_JSON.write_text(json.dumps(snapshot, indent=2) + "\n")

    return [
        ("sweep.grid_configs", float(n), "cartesian design-space grid"),
        ("sweep.scalar_configs_per_s", scalar_cps,
         f"dataclass loop over {SCALAR_SAMPLES} sampled configs"),
        ("sweep.vector_configs_per_s", vector_cps,
         f"jit/vmap evaluate_grid post-compile (compile {compile_s:.2f}s)"),
        ("sweep.speedup", speedup, "vector over scalar configs/sec"),
        ("sweep.best_power_mw", best["avg_power"] * 1e3,
         f"cut={best['cut']} sensor={best['sensor_node']}"
         f"/{best['weight_mem']} detfps={best['detnet_fps']:g}"),
    ]


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")
    print(f"(snapshot written to {BENCH_JSON})")
