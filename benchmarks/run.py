"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>]

Emits ``name,value,derived`` CSV rows:
  * power_tables  — Fig. 5a / Fig. 5b / Table 2 reproduction
  * rbe_roofline  — Fig. 4 RBE accelerator roofline
  * tpu_roofline  — the 40-cell (arch x shape) TPU roofline + energy table
  * kernel_bench  — Pallas kernel validation/timing + VMEM budgets
  * dosc_advisor  — the two-tier (ICI/DCN) communication-plan table
  * sweep_bench   — scalar vs vectorized design-space engine throughput
                    (also snapshots BENCH_sweep.json for the perf trail)
  * pareto_bench  — Pareto-front extraction + gradient knob-search
                    throughput (snapshots BENCH_pareto.json)
"""

from __future__ import annotations

import argparse
import sys
import time


def dosc_advisor_rows():
    from repro.core import dosc
    out = []
    ranked = dosc.advise(grad_elems_per_chip=100e6, pods=2,
                         intra_pod_chips=256, objective="time")
    for c in ranked:
        out.append((f"dosc.{c.plan.name}.t_comm_ms", c.t_comm_s * 1e3,
                    f"dcn_edge={c.dcn_edge_bytes/2**20:.1f}MiB "
                    f"e={c.e_comm_j*1e3:.2f}mJ/chip"))
    flat = next(c for c in ranked if c.plan.name == "flat-ar-f32")
    best = ranked[0]
    out.append(("dosc.best_vs_flat_speedup",
                flat.t_comm_s / best.t_comm_s,
                f"best={best.plan.name} (the paper's two-tier insight)"))
    return out


SUITES = ["power_tables", "rbe_roofline", "tpu_roofline", "kernel_bench",
          "dosc_advisor", "sweep_bench", "pareto_bench"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=SUITES)
    args = ap.parse_args()
    suites = [args.only] if args.only else SUITES
    print("name,value,derived")
    t0 = time.time()
    failures = 0
    for s in suites:
        try:
            if s == "dosc_advisor":
                rows = dosc_advisor_rows()
            else:
                mod = __import__(f"benchmarks.{s}", fromlist=["rows"])
                rows = mod.rows()
            for name, val, derived in rows:
                print(f"{name},{val:.6g},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{s}.FAILED,0,{type(e).__name__}: {e}")
    print(f"benchmarks.wall_s,{time.time()-t0:.1f},"
          f"{len(suites)} suites, {failures} failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
