"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>] [--smoke]

Emits ``name,value,derived`` CSV rows:
  * power_tables  — Fig. 5a / Fig. 5b / Table 2 reproduction
  * rbe_roofline  — Fig. 4 RBE accelerator roofline
  * tpu_roofline  — the 40-cell (arch x shape) TPU roofline + energy table
  * kernel_bench  — Pallas kernel validation/timing + VMEM budgets
  * dosc_advisor  — the two-tier (ICI/DCN) communication-plan table
  * sweep_bench   — scalar vs vectorized design-space engine throughput
                    (also snapshots BENCH_sweep.json for the perf trail)
  * pareto_bench  — Pareto-front extraction + gradient knob-search
                    throughput (snapshots BENCH_pareto.json)
  * stream_bench  — streaming vs dense sweep executor: throughput + peak
                    RSS at 10^5..10^7 configs (snapshots BENCH_stream.json)
  * scenario_bench — session scenario engine: closed-form oracles +
                    10^6 (config x trace) streaming throughput over the
                    battery/thermal channels (BENCH_scenario.json)
  * service_bench — worker-pool scale-out: 1/2/4-worker throughput at
                    10^7 configs + p50/p95 ticket latency under 8
                    tenants, bitwise-anchored (BENCH_service.json)

``--smoke`` runs the fast CI gate instead: a sequence of *named steps*
(tiny grids, hard asserts), each bounded by a per-step SIGALRM timeout
(``REPRO_SMOKE_STEP_TIMEOUT_S``, default 300 s) so one wedged step
fails loudly with its name instead of hanging the whole CI job:
exact streaming/dense parity (argmin, top-k, Pareto front, counts),
async double-buffered pipeline parity across prefetch depths, the
backend registry (``backend="pallas"`` in interpret mode and
``scan_chunks=4`` fused dispatch, both exact vs dense), compiled
``constraints=`` masking vs the dense host post-filter,
stacked-workload parity end-to-end, the scenario engine
(constant-trace degeneracy bitwise vs the static kernel, the
time-to-empty closed-form oracle, and session-channel
argmin/top-k(maximize) stream-vs-dense parity), the fault-tolerance
recovery paths — a SIGKILLed checkpointed sweep must resume in a fresh
process with bitwise-identical results, and seeded transient faults
must retry to exact parity — and the sweep service: a served request
must match the solo run bitwise, a deadline-exceeded request must
return a consistent prefix snapshot, an over-capacity submission must
be rejected without disturbing admitted work, and a SIGKILL'd server
restarted over its spool must resume to bitwise-identical results.
The networked path has its own gates: ``net-kill-reconnect`` SIGKILLs
a *listening* server mid-request with a connected client and requires
the client to reconnect, dedupe its idempotent resubmit onto the
recovered ticket and decode a bitwise-identical result; and
``net-fairness`` asserts the 1:3 weight share under sustained
overload, priority aging (no starvation), and wire-carried
backpressure fields (depth, capacity, tenant, retry-after);
``net-scaleout`` serves a watched request through a 2-process worker
pool behind an HMAC-authenticated server — bitwise parity, >= 2
leased parts folded, per-chunk deltas on the wire, bad tokens
rejected before parsing; and ``worker-kill-reclaim`` SIGKILLs one of
three live workers mid-lease and requires the survivors to reclaim
the orphaned lease (attempt >= 2) and drain to the bitwise solo
answer.
Perf-path *and* resilience regressions fail CI, not just benchmarks.
"""

from __future__ import annotations

import argparse
import contextlib
import os
import signal as _signal
import sys
import threading
import time

#: Per-smoke-step watchdog (seconds); override with the env var.
SMOKE_STEP_TIMEOUT_ENV = "REPRO_SMOKE_STEP_TIMEOUT_S"
DEFAULT_SMOKE_STEP_TIMEOUT_S = 300.0


class SmokeStepTimeout(RuntimeError):
    """A smoke step exceeded its watchdog — named, so CI logs say
    *which* gate wedged instead of timing out the whole job."""


@contextlib.contextmanager
def _step_timeout(name: str, seconds: float):
    """SIGALRM watchdog around one smoke step (main thread only; a
    no-op where SIGALRM is unavailable, e.g. Windows)."""
    usable = (seconds > 0 and hasattr(_signal, "SIGALRM")
              and threading.current_thread() is threading.main_thread())
    if not usable:
        yield
        return

    def _alarm(signum, frame):
        raise SmokeStepTimeout(
            f"smoke step '{name}' exceeded {seconds:.0f}s "
            f"(raise {SMOKE_STEP_TIMEOUT_ENV} if the host is just slow)")

    prev = _signal.signal(_signal.SIGALRM, _alarm)
    _signal.setitimer(_signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        _signal.setitimer(_signal.ITIMER_REAL, 0.0)
        _signal.signal(_signal.SIGALRM, prev)


def dosc_advisor_rows():
    from repro.core import dosc
    out = []
    ranked = dosc.advise(grad_elems_per_chip=100e6, pods=2,
                         intra_pod_chips=256, objective="time")
    for c in ranked:
        out.append((f"dosc.{c.plan.name}.t_comm_ms", c.t_comm_s * 1e3,
                    f"dcn_edge={c.dcn_edge_bytes/2**20:.1f}MiB "
                    f"e={c.e_comm_j*1e3:.2f}mJ/chip"))
    flat = next(c for c in ranked if c.plan.name == "flat-ar-f32")
    best = ranked[0]
    out.append(("dosc.best_vs_flat_speedup",
                flat.t_comm_s / best.t_comm_s,
                f"best={best.plan.name} (the paper's two-tier insight)"))
    return out


SUITES = ["power_tables", "rbe_roofline", "tpu_roofline", "kernel_bench",
          "dosc_advisor", "sweep_bench", "pareto_bench", "stream_bench",
          "scenario_bench", "service_bench"]


def _smoke_stream_parity(ctx):
    """Dense reference + exact streaming parity (shared by later steps)."""
    import numpy as np

    from repro.core import pareto, stream, sweep

    grid_kw = dict(sensor_nodes=("7nm", "16nm"),
                   weight_mems=("sram", "mram"),
                   detnet_fps=(5.0, 30.0))     # 34 cuts x 2x2x2 = 272
    dense = sweep.evaluate_grid(**grid_kw)
    res = stream.stream_grid(**grid_kw, chunk_size=97, track="all",
                             hist_bins=8)
    assert all(res.argmin(f) == dense.argmin(f) for f in sweep.FIELDS), \
        "streaming argmin drifted from dense"
    assert all(res.top_k(o) == dense.top_k(o, 4)
               for o in res.objectives), "streaming top-k drifted"
    df, sf = pareto.pareto_front(dense), res.pareto_front()
    assert np.array_equal(df.indices, sf.indices) and \
        np.array_equal(df.values, sf.values), "streaming front drifted"
    assert all(res.finite_counts[f] ==
               int(np.isfinite(dense.data[f]).sum())
               for f in sweep.FIELDS), "validity counts drifted"
    ctx.update(grid_kw=grid_kw, dense=dense, res=res, df=df)
    return [
        ("smoke.stream_dense_parity", 1.0,
         f"argmin/top-k/front/counts exact on {dense.n_configs} configs"),
        ("smoke.front_size", float(sf.size), "reference-front members"),
    ]


def _smoke_async_pipeline(ctx):
    """Prefetch depths (0 = synchronous reference) change no result."""
    import numpy as np

    from repro.core import stream

    grid_kw, dense, df = ctx["grid_kw"], ctx["dense"], ctx["df"]
    piped = stream.stream_grid(**grid_kw, chunk_size=97, prefetch=4)
    sync = stream.stream_grid(**grid_kw, chunk_size=97, prefetch=0)
    for r in (piped, sync):
        assert all(r.argmin(o) == dense.argmin(o)
                   for o in r.objectives), "async pipeline drifted"
        pf = r.pareto_front()
        assert np.array_equal(pf.indices, df.indices) and \
            np.array_equal(pf.values, df.values), "async front drifted"
    return [("smoke.async_pipeline_parity", 1.0,
             "prefetch 0/4 exact vs dense (double-buffered path)")]


def _smoke_constraints(ctx):
    """Compiled constraint predicates == dense host post-filter."""
    import numpy as np

    from repro.core import pareto, stream

    grid_kw, dense = ctx["grid_kw"], ctx["dense"]
    lat_budget = float(np.nanquantile(dense.data["latency"], 0.5))
    cons = {"latency": lat_budget}
    constrained = stream.stream_grid(**grid_kw, chunk_size=97,
                                     constraints=cons, prefetch=4)
    dense_con = dense.constrain(cons)
    assert constrained.argmin() == dense_con.argmin(), \
        "constrained argmin drifted from host post-filter"
    cf, dcf = constrained.pareto_front(), pareto.pareto_front(dense_con)
    assert np.array_equal(cf.indices, dcf.indices) and \
        np.array_equal(cf.values, dcf.values), "constrained front drifted"
    assert constrained.finite_counts["latency"] == \
        int(np.isfinite(dense_con.data["latency"]).sum()), \
        "feasible counts drifted"
    return [("smoke.constrained_parity", 1.0,
             f"compiled latency<= {lat_budget:.3g} mask == dense "
             f"post-filter")]


def _smoke_backends(ctx):
    """Pallas (interpret on CPU) + scan-fused dispatch, exact vs dense."""
    import numpy as np

    from repro.core import stream, sweep

    grid_kw, dense, df = ctx["grid_kw"], ctx["dense"], ctx["df"]
    pallas = stream.stream_grid(**grid_kw, chunk_size=97, track="all",
                                backend="pallas")
    assert all(pallas.argmin(f) == dense.argmin(f)
               for f in sweep.FIELDS), "pallas backend argmin drifted"
    assert all(pallas.top_k(o) == dense.top_k(o, 4)
               for o in pallas.objectives), "pallas backend top-k drifted"
    pf = pallas.pareto_front()
    assert np.array_equal(pf.indices, df.indices) and \
        np.array_equal(pf.values, df.values), "pallas front drifted"
    dense_pallas = sweep.evaluate_grid(**grid_kw, backend="pallas")
    assert all(np.array_equal(dense.data[f], dense_pallas.data[f],
                              equal_nan=True)
               for f in sweep.FIELDS), "pallas dense eval drifted"
    scanned = stream.stream_grid(**grid_kw, chunk_size=97, scan_chunks=4,
                                 prefetch=4)
    assert all(scanned.argmin(o) == dense.argmin(o)
               for o in scanned.objectives), "scan-fused argmin drifted"
    sc = scanned.pareto_front()
    assert np.array_equal(sc.indices, df.indices) and \
        np.array_equal(sc.values, df.values), "scan-fused front drifted"
    return [
        ("smoke.pallas_backend_parity", 1.0,
         "backend='pallas' (interpret) exact vs dense: stream + grid"),
        ("smoke.scan_fused_parity", 1.0,
         "scan_chunks=4 fused dispatch exact vs dense"),
    ]


def _smoke_stacked(ctx):
    """Stacked-workload axis: every model row reproduces its own grid;
    optimal_partition routes sequence knobs through the grid engines."""
    import numpy as np

    from repro.core import partition, sweep
    from repro.core.handtracking import build_detnet, build_keynet

    det, key = build_detnet(), build_keynet()
    pairs = ((det, key), (det.scaled(0.5), key))
    stacked = sweep.evaluate_grid(models=pairs, detnet_fps=(10.0, 30.0))
    for mi, (d_wl, k_wl) in enumerate(pairs):
        single = sweep.evaluate_grid(detnet=d_wl, keynet=k_wl,
                                     detnet_fps=(10.0, 30.0))
        a, b = stacked.avg_power[mi], single.avg_power
        ok = np.isfinite(a) & np.isfinite(b)
        rel = np.abs(a[ok] - b[ok]) / np.maximum(np.abs(b[ok]), 1e-30)
        assert rel.max() <= 1e-6, f"stacked model {mi} drifted: {rel.max()}"
    best = partition.optimal_partition(sensor_node=("7nm", "16nm"))
    assert best.avg_power <= partition.optimal_partition().avg_power * (
        1 + 1e-12)
    return [("smoke.stacked_parity", 1.0,
             f"{len(pairs)} stacked models <=1e-6 vs single grids")]


def _smoke_scenario(ctx):
    """Scenario engine: constant-trace degeneracy bitwise vs the static
    kernel, time-to-empty closed form, stream-vs-dense session parity."""
    import numpy as np

    from repro.core import scenario as SC
    from repro.core import stream, sweep
    from repro.core.constants import DEFAULT_BATTERY

    grid_kw, dense = ctx["grid_kw"], ctx["dense"]
    const = SC.ScenarioSet(
        traces=(SC.ScenarioTrace("const", (SC.Phase(600.0),)),),
        throttle=False)
    scen = sweep.evaluate_grid(**grid_kw, scenarios=const)
    assert all(np.array_equal(dense.data[f], scen.data[f][..., 0],
                              equal_nan=True) for f in sweep.FIELDS), \
        "constant-trace degeneracy drifted from the static kernel"
    P = scen.data["avg_power"][..., 0]
    okm = np.isfinite(P)
    tte_ref = DEFAULT_BATTERY.soc0 * DEFAULT_BATTERY.capacity_j / P[okm]
    tte_err = float(np.max(np.abs(
        scen.data["time_to_empty_s"][..., 0][okm] - tte_ref) / tte_ref))
    assert tte_err <= 1e-6, f"time-to-empty oracle drift: {tte_err}"
    scen_obj = ("time_to_empty_s", "peak_case_temp_c")
    scen_ref = sweep.evaluate_grid(**grid_kw, scenarios="all")
    scen_stream = stream.stream_grid(
        **grid_kw, scenarios="all", chunk_size=97, objectives=scen_obj,
        maximize=("time_to_empty_s",))
    assert scen_stream.argmin("peak_case_temp_c")["peak_case_temp_c"] == \
        np.nanmin(scen_ref.data["peak_case_temp_c"]), \
        "scenario streaming argmin drifted from dense"
    tr = scen_ref.data["time_to_empty_s"]
    assert scen_stream.top_k("time_to_empty_s")[0]["time_to_empty_s"] \
        == np.nanmax(tr[np.isfinite(tr)]), \
        "scenario top-k(maximize) drifted from dense"
    return [
        ("smoke.scenario_oracle_parity", 1.0,
         f"const-trace degeneracy bitwise; tte oracle <= {tte_err:.2g}"),
        ("smoke.scenario_stream_parity", 1.0,
         f"session argmin/top-k(maximize) exact on "
         f"{scen_ref.n_configs} (config x trace)"),
    ]


def _smoke_transient_faults(ctx):
    """Seeded transient faults retry in place to untouched results."""
    import numpy as np

    from repro.core import stream, sweep
    from repro.runtime import FaultInjector, FaultPlan

    grid_kw, dense, df = ctx["grid_kw"], ctx["dense"], ctx["df"]
    inj = FaultInjector(FaultPlan(fail_chunks=(1,), transient_rate=0.5,
                                  seed=3))
    faulted = stream.stream_grid(**grid_kw, chunk_size=97, track="all",
                                 fault_injector=inj)
    assert inj.injected["transient"] >= 1, "no transient faults fired"
    assert faulted.stats["retries"] == inj.injected["transient"], \
        "retry accounting drifted from injected fault count"
    assert all(faulted.argmin(f) == dense.argmin(f)
               for f in sweep.FIELDS), "retried sweep argmin drifted"
    ff = faulted.pareto_front()
    assert np.array_equal(ff.indices, df.indices) and \
        np.array_equal(ff.values, df.values), "retried sweep front drifted"
    return [("smoke.transient_fault_parity", 1.0,
             f"{int(faulted.stats['retries'])} injected faults retried "
             f"to exact parity")]


def _smoke_kill_resume_step(ctx):
    """SIGKILL a checkpointed sweep mid-flight in a subprocess, resume
    in a fresh process, require bitwise-identical deliverables."""
    resumed_step = _smoke_kill_resume(ctx["grid_kw"])
    return [("smoke.kill_resume_parity", 1.0,
             f"SIGKILL at chunk 2 -> resumed from step {resumed_step} "
             f"bitwise-identical")]


def _smoke_service(ctx):
    """The sweep service end to end: served-request bitwise parity,
    deadline partial snapshot (consistent prefix), backpressure
    rejection without disturbing admitted work, and server SIGKILL ->
    restart -> bitwise resume over the same spool."""
    import numpy as np

    from repro.core.service import SweepRequest, SweepService
    from repro.runtime import BackpressureError, FaultInjector, FaultPlan

    grid_kw, dense, ref = ctx["grid_kw"], ctx["dense"], ctx["res"]
    req = SweepRequest(grid=grid_kw, track="all", chunk_size=97,
                       hist_bins=8)

    # (a) A served request reproduces the solo stream run bitwise.
    with SweepService() as svc:
        served = svc.submit(req).result(timeout=600)
    assert not served.partial
    assert served.min_val == ref.min_val and \
        served.min_idx == ref.min_idx, "served argmin drifted from solo"
    assert np.array_equal(served.topk_idx, ref.topk_idx) and \
        np.array_equal(served.topk_val, ref.topk_val), \
        "served top-k drifted from solo"
    assert np.array_equal(served.front_indices, ref.front_indices) and \
        np.array_equal(served.front_values, ref.front_values), \
        "served front drifted from solo"

    # (b) Deadline-exceeded request: consistent partial prefix snapshot.
    inj = FaultInjector(FaultPlan(straggle={1: 2.0}))
    with SweepService(fault_injector=inj) as svc:
        part = svc.submit(SweepRequest(
            grid=grid_kw, chunk_size=97,
            deadline_s=0.5)).result(timeout=600)
        n_expired = svc.health()["counters"]["deadline_expired"]
    assert part.partial, "deadline did not yield a partial snapshot"
    frac = part.stats["fraction_complete"]
    assert 0.0 < frac < 1.0, f"fraction_complete {frac} out of range"
    assert n_expired == 1, "deadline_expired counter drifted"
    base = round(frac * dense.data["avg_power"].size)
    for field in part.objectives:
        prefix = np.asarray(dense.data[field]).ravel()[:base]
        assert part.min_val[field] == float(np.nanmin(prefix)), \
            f"partial snapshot not prefix-consistent on {field}"

    # (c) Backpressure: over-capacity submission rejected with depth/cap,
    # admitted work unaffected.
    with SweepService(capacity=1) as svc:
        svc.pause()
        admitted = svc.submit(req)
        try:
            svc.submit(req)
            raise AssertionError("over-capacity submit was not rejected")
        except BackpressureError as e:
            assert e.queue_depth == 1 and e.capacity == 1
        svc.resume()
        ok = admitted.result(timeout=600)
    assert not ok.partial and ok.min_val == ref.min_val, \
        "backpressure rejection disturbed admitted work"

    # (d) SIGKILL the server mid-request; a restart over the same spool
    # resumes the journaled request to the bitwise solo answer.
    resumed_step = _smoke_service_kill_resume(grid_kw)
    return [
        ("smoke.service_request_parity", 1.0,
         "served request bitwise == solo stream run"),
        ("smoke.service_deadline_partial", 1.0,
         f"deadline snapshot prefix-consistent at {frac:.0%}"),
        ("smoke.service_backpressure", 1.0,
         "over-capacity submit rejected; admitted work exact"),
        ("smoke.service_kill_resume", 1.0,
         f"server SIGKILL -> restart resumed from step {resumed_step} "
         f"bitwise-identical"),
    ]


def _smoke_net_kill_reconnect(ctx):
    """The networked chaos gate: SIGKILL a listening server mid-request
    with a connected client; the client must reconnect to the restarted
    server, its idempotent resubmit must dedupe onto the recovered
    ticket (same request id, no double execution), and the final result
    must be bitwise-identical to the fault-free in-process run."""
    import json
    import os
    import signal
    import subprocess
    import sys
    import tempfile
    import threading

    import numpy as np

    from repro.core import stream
    from repro.core.client import SweepClient
    from repro.core.service import SweepRequest

    # Enough steps (~88 x 31-config chunks, several hundred ms of
    # steady-state work after the first snapshot) that the kill
    # reliably lands mid-request, not after completion.
    grid_kw = dict(ctx["grid_kw"],
                   detnet_fps=tuple(float(f) for f in range(5, 105, 5)))
    req = SweepRequest(grid=grid_kw, track="all", chunk_size=31, top_k=4)

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=1")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])

    def start_server(sock_path, spool):
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.service", "--unix", sock_path,
             "--spool", spool, "--checkpoint-every-steps", "1"],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
            text=True)
        ready = json.loads(proc.stdout.readline())
        assert ready["listening"] == sock_path, f"bad ready line {ready}"
        return proc

    with tempfile.TemporaryDirectory(prefix="smoke_net_") as tmp:
        sock_path = os.path.join(tmp, "svc.sock")
        spool = os.path.join(tmp, "spool")
        server_a = start_server(sock_path, spool)
        cli = SweepClient(sock_path, reconnect_timeout_s=240.0,
                          heartbeat_grace_s=8.0)
        ticket = cli.submit(req, client_id="smoke-chaos-1")
        first_id = ticket.id
        seen = {"frac": 0.0}
        box: dict = {}

        def wait_result():
            try:
                box["res"] = ticket.result(
                    timeout=600,
                    on_progress=lambda s: seen.__setitem__(
                        "frac", s["fraction_complete"]))
            except BaseException as e:
                box["err"] = e

        th = threading.Thread(target=wait_result)
        th.start()
        deadline = time.time() + 300
        while seen["frac"] == 0.0 and th.is_alive() \
                and time.time() < deadline:
            time.sleep(0.02)
        assert seen["frac"] > 0.0, "no progress snapshot before kill"
        server_a.kill()
        server_a.wait(30)
        server_b = start_server(sock_path, spool)
        try:
            th.join(600)
            assert "err" not in box, \
                f"client failed across restart: {box.get('err')!r}"
            res = box["res"]
            assert ticket.id == first_id, \
                "idempotent resubmit minted a new ticket"
            assert res.stats["resumed_from_step"] > 0, res.stats
            assert cli.counters["reconnects"] >= 2, cli.counters
            ref = stream.stream_grid(**grid_kw, track="all",
                                     chunk_size=31, top_k=4)
            assert res.min_val == ref.min_val and \
                res.min_idx == ref.min_idx, "networked argmin drifted"
            assert np.array_equal(res.topk_idx, ref.topk_idx) and \
                np.array_equal(res.topk_val, ref.topk_val), \
                "networked top-k drifted"
            assert np.array_equal(res.front_indices,
                                  ref.front_indices) and \
                np.array_equal(res.front_values, ref.front_values), \
                "networked front drifted"
        finally:
            cli.close()
            server_b.send_signal(signal.SIGTERM)
            server_b.wait(60)
    return [("smoke.net_kill_reconnect", 1.0,
             f"server SIGKILL -> client reconnect + dedupe resumed from "
             f"step {int(res.stats['resumed_from_step'])} bitwise")]


def _smoke_net_fairness(ctx):
    """The fairness gate: tenants at weights 1:3 under sustained
    overload converge to their weight share of claimed work (within
    10%), a starved low-priority request ages past fresh high-priority
    arrivals, and over-the-wire overload rejections carry queue depth
    and a retry-after hint."""
    import tempfile

    from repro.core.client import SweepClient
    from repro.core.service import SweepRequest, SweepService
    from repro.runtime import (AdmissionQueue, BackpressureError,
                               SweepServer, TenantPolicy)

    # (a) Deficit round-robin weight share under sustained overload.
    q = AdmissionQueue(4096, tenants={"small": TenantPolicy(weight=1.0),
                                      "big": TenantPolicy(weight=3.0)})
    for i in range(600):
        q.offer(f"s{i}", tenant="small")
        q.offer(f"b{i}", tenant="big")
    n_big = 0
    for _ in range(400):
        (item,) = q.take_batch(timeout=1.0)
        tenant = "big" if item.startswith("b") else "small"
        n_big += tenant == "big"
        q.release(tenant)
    share = n_big / 400.0
    assert abs(share - 0.75) <= 0.10, \
        f"weight-1:3 share drifted to {share:.2f}"

    # (b) Aging: a starved low-priority request eventually runs.
    aq = AdmissionQueue(8, aging_s=0.02)
    aq.offer("starved", priority=0)
    time.sleep(0.09)
    aq.offer("fresh-high", priority=2)
    assert aq.take_batch(timeout=1.0) == ["starved"], \
        "low-priority request starved behind fresh high-priority work"

    # (c) Overload rejections over the wire keep the in-process
    # BackpressureError semantics: depth, capacity, tenant, retry hint.
    grid_kw = ctx["grid_kw"]
    req = SweepRequest(grid=grid_kw, chunk_size=97)
    with tempfile.TemporaryDirectory(prefix="smoke_fair_") as tmp:
        svc = SweepService(capacity=2)
        svc.set_tenant("capped", weight=1.0, max_pending=1)
        svc.pause()
        with SweepServer(svc, unix_path=f"{tmp}/svc.sock",
                         own_service=True) as server:
            with SweepClient(server.address) as cli:
                t1 = cli.submit(SweepRequest(grid=grid_kw, chunk_size=97,
                                             tenant="capped"))
                try:
                    cli.submit(SweepRequest(grid=grid_kw, chunk_size=101,
                                            tenant="capped"))
                    raise AssertionError(
                        "tenant over-cap submit was not rejected")
                except BackpressureError as e:
                    assert e.tenant == "capped", e
                    assert e.queue_depth == 1 and e.capacity == 1, e
                    assert e.retry_after_s is not None and \
                        e.retry_after_s > 0, e
                t2 = cli.submit(req)    # other tenants unaffected
                try:
                    cli.submit(SweepRequest(grid=grid_kw,
                                            chunk_size=103))
                    raise AssertionError(
                        "over-capacity submit was not rejected")
                except BackpressureError as e:
                    assert e.tenant is None and e.queue_depth == 2, e
                    assert e.retry_after_s is not None, e
                for t in (t1, t2):
                    cli.cancel(t.id)
            svc.resume()
            server.close(drain=True, timeout=30.0)
    return [
        ("smoke.net_fairness_share", share,
         "weights 1:3 under overload: big-tenant share within 10% of "
         "0.75"),
        ("smoke.net_fairness_aging", 1.0,
         "starved low-priority request aged past fresh high-priority"),
        ("smoke.net_fairness_backpressure", 1.0,
         "wire rejections carry depth/capacity/tenant/retry-after"),
    ]


def _smoke_net_scaleout(ctx):
    """The scale-out gate: a SweepService with a 2-process worker pool
    behind an HMAC-authenticated SweepServer must serve a watched
    request bitwise-identical to the solo run, fold >= 2 leased parts,
    stream per-chunk deltas after the first full snapshot, and reject
    a bad token before parsing any frame."""
    import tempfile

    import numpy as np

    from repro.core import stream
    from repro.core.client import AuthenticationError, SweepClient
    from repro.core.service import SweepRequest, SweepService
    from repro.runtime import SweepServer

    grid_kw = ctx["grid_kw"]
    req = SweepRequest(grid=grid_kw, track="all", chunk_size=31,
                       scan_chunks=1, top_k=4)
    ref = stream.stream_grid(**grid_kw, track="all", chunk_size=31,
                             scan_chunks=1, top_k=4)
    with tempfile.TemporaryDirectory(prefix="smoke_scaleout_") as tmp:
        svc = SweepService(capacity=8, snapshot_every_s=0.0, workers=2,
                           spool_dir=f"{tmp}/spool")
        with SweepServer(svc, unix_path=f"{tmp}/svc.sock",
                         own_service=True, heartbeat_s=0.1,
                         auth_token="smoke-secret") as server:
            try:
                with SweepClient(server.address, auth="bad-token") as bad:
                    bad.ping()
                raise AssertionError("bad auth token was accepted")
            except AuthenticationError:
                pass
            assert server.counters["auth_failures"] >= 1
            with SweepClient(server.address,
                             auth="smoke-secret") as cli:
                snaps: list = []
                t = cli.submit(req, client_id="smoke-scaleout-1")
                res = t.result(timeout=600,
                               on_progress=snaps.append)
                tr = cli.health()["transport"]
            assert svc.counters["pooled_executions"] == 1, svc.counters
            assert res.stats["n_parts"] >= 2, res.stats
            assert res.stats["watch_wire_bytes"] > 0, res.stats
            assert tr["watch_delta_bytes"] > 0, tr
            assert all(s["fraction_complete"] <=
                       s2["fraction_complete"] for s, s2 in
                       zip(snaps, snaps[1:])), "snapshots regressed"
        assert res.min_val == ref.min_val and \
            res.min_idx == ref.min_idx, "pooled argmin drifted"
        assert np.array_equal(res.topk_idx, ref.topk_idx) and \
            np.array_equal(res.topk_val, ref.topk_val), \
            "pooled top-k drifted"
        assert np.array_equal(res.front_indices, ref.front_indices) \
            and np.array_equal(res.front_values, ref.front_values), \
            "pooled front drifted"
    return [("smoke.net_scaleout", 1.0,
             f"2-worker pool behind auth'd server: "
             f"{int(res.stats['n_parts'])} parts folded bitwise, "
             f"deltas on the wire")]


def _smoke_worker_kill_reclaim(ctx):
    """The reclaim gate: SIGKILL one live worker of a pool mid-lease;
    the survivors must reclaim the orphaned lease after its heartbeat
    expires (attempt >= 2) and drain the job to the bitwise solo
    answer."""
    import os
    import signal
    import tempfile

    import numpy as np

    from repro.core import stream
    from repro.core.service import SweepRequest
    from repro.runtime import workers as wk

    grid_kw = ctx["grid_kw"]
    req = SweepRequest(grid=grid_kw, track="all", chunk_size=31,
                       scan_chunks=1, top_k=4)
    ref = stream.stream_grid(**grid_kw, track="all", chunk_size=31,
                             scan_chunks=1, top_k=4)
    with tempfile.TemporaryDirectory(prefix="smoke_reclaim_") as spool:
        handle = wk.dispatch_job(spool, req, n_leases=6,
                                 checkpoint_every_steps=1)
        with wk.WorkerPool(spool, 3, ttl_s=2.0, respawn=False) as pool:
            victim = None
            deadline = time.time() + 240
            while victim is None and time.time() < deadline:
                st = handle.poll()
                if st["done"]:
                    break
                for ls in st["leases"]:
                    if ls["state"] == "leased" \
                            and ls["owner"] in pool.pids():
                        victim = int(ls["owner"])
                        break
                time.sleep(0.02)
            assert victim is not None, "no worker claimed a lease"
            os.kill(victim, signal.SIGKILL)
            deadline = time.time() + 240
            while time.time() < deadline:
                st = handle.poll()
                assert not st["failed"], st["failed"]
                if st["done"]:
                    break
                time.sleep(0.1)
            st = handle.poll()
            assert st["done"], f"job did not drain: {st['states']}"
        attempts = max(int(ls["attempt"]) for ls in st["leases"])
        assert attempts >= 2, \
            "killed worker's lease was never reclaimed"
        res = handle.result()
        assert res.min_val == ref.min_val and \
            res.min_idx == ref.min_idx, "reclaimed argmin drifted"
        assert np.array_equal(res.topk_idx, ref.topk_idx) and \
            np.array_equal(res.topk_val, ref.topk_val), \
            "reclaimed top-k drifted"
        assert np.array_equal(res.front_indices, ref.front_indices) \
            and np.array_equal(res.front_values, ref.front_values), \
            "reclaimed front drifted"
    return [("smoke.worker_kill_reclaim", 1.0,
             f"worker SIGKILL -> lease reclaimed (max attempt "
             f"{attempts}) -> {int(res.stats['n_parts'])} parts folded "
             f"bitwise")]


#: The named, individually-timed smoke steps, in dependency order
#: (``stream_parity`` seeds the shared dense reference).
SMOKE_STEPS = [
    ("stream_parity", _smoke_stream_parity),
    ("async_pipeline", _smoke_async_pipeline),
    ("constraints", _smoke_constraints),
    ("backends", _smoke_backends),
    ("stacked", _smoke_stacked),
    ("scenario", _smoke_scenario),
    ("transient_faults", _smoke_transient_faults),
    ("kill_resume", _smoke_kill_resume_step),
    ("service", _smoke_service),
    ("net-kill-reconnect", _smoke_net_kill_reconnect),
    ("net-fairness", _smoke_net_fairness),
    ("net-scaleout", _smoke_net_scaleout),
    ("worker-kill-reclaim", _smoke_worker_kill_reclaim),
]


def smoke_rows(step_timeout_s: float | None = None):
    """Fast CI gate: run every named smoke step under its watchdog."""
    if step_timeout_s is None:
        step_timeout_s = float(os.environ.get(
            SMOKE_STEP_TIMEOUT_ENV, DEFAULT_SMOKE_STEP_TIMEOUT_S))
    ctx: dict = {}
    rows = []
    for name, fn in SMOKE_STEPS:
        t0 = time.time()
        with _step_timeout(name, step_timeout_s):
            rows.extend(fn(ctx))
        rows.append((f"smoke.step.{name}.wall_s", time.time() - t0,
                     f"<= {step_timeout_s:.0f}s watchdog"))
    return rows


def _smoke_kill_resume(grid_kw: dict) -> int:
    """SIGKILL a checkpointed subprocess sweep, resume in a fresh one.

    Returns the resumed-from step index (> 0).  The resume child
    recomputes the dense reference itself and asserts bitwise parity on
    every deliverable, so the gate fails on any divergence, not just a
    crash."""
    import json
    import os
    import signal
    import subprocess
    import sys
    import tempfile

    with tempfile.TemporaryDirectory(prefix="smoke_ckpt_") as ckpt:
        common = f"""
import numpy as np
from repro.core import pareto, stream, sweep
GRID = {grid_kw!r}
KW = dict(chunk_size=97, top_k=4, track="all",
          checkpoint_dir={ckpt!r}, checkpoint_every_steps=1)
"""
        kill = common + """
from repro.runtime import FaultInjector, FaultPlan
inj = FaultInjector(FaultPlan(kill_at=2))
stream.stream_grid(**GRID, **KW, fault_injector=inj)
raise SystemExit("unreachable: SIGKILL did not fire")
"""
        resume = common + """
import json
dense = sweep.evaluate_grid(**GRID)
res = stream.stream_grid(**GRID, **KW)
assert res.stats["resumed_from_step"] > 0, res.stats
assert all(res.argmin(f) == dense.argmin(f) for f in sweep.FIELDS)
assert all(res.top_k(o) == dense.top_k(o, 4) for o in res.objectives)
df = pareto.pareto_front(dense); sf = res.pareto_front()
assert np.array_equal(df.indices, sf.indices)
assert np.array_equal(df.values, sf.values)
print(json.dumps({"resumed_from_step": res.stats["resumed_from_step"]}))
"""
        env = dict(os.environ)
        # Pin the child to one device so the dispatch geometry (and
        # with it the kill_at trigger) is independent of any inherited
        # ``XLA_FLAGS`` — appending wins, the last flag takes effect.
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=1")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
               if p])
        out1 = subprocess.run([sys.executable, "-c", kill], env=env,
                              capture_output=True, text=True, timeout=600)
        assert out1.returncode == -signal.SIGKILL, (
            f"kill child exited {out1.returncode}, expected SIGKILL: "
            f"{out1.stderr[-1000:]}")
        out2 = subprocess.run([sys.executable, "-c", resume], env=env,
                              capture_output=True, text=True, timeout=600)
        assert out2.returncode == 0, \
            f"resume child failed: {out2.stderr[-2000:]}"
        return int(json.loads(out2.stdout.strip().splitlines()[-1])
                   ["resumed_from_step"])


def _smoke_service_kill_resume(grid_kw: dict) -> int:
    """SIGKILL a spool-backed SweepService mid-request; a fresh service
    over the same spool must re-admit the journaled request and resume
    it to the bitwise solo-run answer.  Returns the resumed step."""
    import json
    import os
    import signal
    import subprocess
    import sys
    import tempfile

    with tempfile.TemporaryDirectory(prefix="smoke_svc_") as spool:
        common = f"""
import numpy as np
from repro.core import stream
from repro.core.service import SweepRequest, SweepService
GRID = {grid_kw!r}
REQ = SweepRequest(grid=GRID, track="all", chunk_size=97, top_k=4)
SPOOL = {spool!r}
"""
        kill = common + """
from repro.runtime import FaultInjector, FaultPlan
inj = FaultInjector(FaultPlan(kill_at=2))
svc = SweepService(spool_dir=SPOOL, checkpoint_every_steps=1,
                   fault_injector=inj)
svc.submit(REQ).result(timeout=600)
raise SystemExit("unreachable: SIGKILL did not fire")
"""
        resume = common + """
import json
svc = SweepService(spool_dir=SPOOL, checkpoint_every_steps=1)
ts = svc.tickets()
assert len(ts) == 1, "recovery did not re-admit the journaled request"
res = ts[0].result(timeout=600)
svc.close()
assert not res.partial
assert res.stats["resumed_from_step"] > 0, res.stats
ref = stream.stream_grid(**GRID, track="all", chunk_size=97, top_k=4)
assert res.min_val == ref.min_val and res.min_idx == ref.min_idx
assert res.finite_counts == ref.finite_counts
assert np.array_equal(res.topk_idx, ref.topk_idx)
assert np.array_equal(res.topk_val, ref.topk_val)
assert np.array_equal(res.front_indices, ref.front_indices)
assert np.array_equal(res.front_values, ref.front_values)
print(json.dumps({"resumed_from_step": res.stats["resumed_from_step"]}))
"""
        env = dict(os.environ)
        # Pin the child to one device (see _smoke_kill_resume_step):
        # the kill_at trigger depends on the dispatch geometry, which
        # inherited ``XLA_FLAGS`` would otherwise change.
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=1")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
               if p])
        out1 = subprocess.run([sys.executable, "-c", kill], env=env,
                              capture_output=True, text=True, timeout=600)
        assert out1.returncode == -signal.SIGKILL, (
            f"service kill child exited {out1.returncode}, expected "
            f"SIGKILL: {out1.stderr[-1000:]}")
        out2 = subprocess.run([sys.executable, "-c", resume], env=env,
                              capture_output=True, text=True, timeout=600)
        assert out2.returncode == 0, \
            f"service resume child failed: {out2.stderr[-2000:]}"
        return int(json.loads(out2.stdout.strip().splitlines()[-1])
                   ["resumed_from_step"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=SUITES)
    ap.add_argument("--smoke", action="store_true",
                    help="fast streaming/dense parity gate (CI)")
    args = ap.parse_args()
    if args.smoke:
        print("name,value,derived")
        t0 = time.time()
        for name, val, derived in smoke_rows():
            print(f"{name},{val:.6g},{derived}")
        print(f"smoke.wall_s,{time.time()-t0:.1f},streaming parity gate")
        return
    suites = [args.only] if args.only else SUITES
    print("name,value,derived")
    t0 = time.time()
    failures = 0
    for s in suites:
        try:
            if s == "dosc_advisor":
                rows = dosc_advisor_rows()
            else:
                mod = __import__(f"benchmarks.{s}", fromlist=["rows"])
                rows = mod.rows()
            for name, val, derived in rows:
                print(f"{name},{val:.6g},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{s}.FAILED,0,{type(e).__name__}: {e}")
    print(f"benchmarks.wall_s,{time.time()-t0:.1f},"
          f"{len(suites)} suites, {failures} failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
