"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>] [--smoke]

Emits ``name,value,derived`` CSV rows:
  * power_tables  — Fig. 5a / Fig. 5b / Table 2 reproduction
  * rbe_roofline  — Fig. 4 RBE accelerator roofline
  * tpu_roofline  — the 40-cell (arch x shape) TPU roofline + energy table
  * kernel_bench  — Pallas kernel validation/timing + VMEM budgets
  * dosc_advisor  — the two-tier (ICI/DCN) communication-plan table
  * sweep_bench   — scalar vs vectorized design-space engine throughput
                    (also snapshots BENCH_sweep.json for the perf trail)
  * pareto_bench  — Pareto-front extraction + gradient knob-search
                    throughput (snapshots BENCH_pareto.json)
  * stream_bench  — streaming vs dense sweep executor: throughput + peak
                    RSS at 10^5..10^7 configs (snapshots BENCH_stream.json)
  * scenario_bench — session scenario engine: closed-form oracles +
                    10^6 (config x trace) streaming throughput over the
                    battery/thermal channels (BENCH_scenario.json)

``--smoke`` runs the fast CI gate instead: tiny grids, asserting exact
streaming/dense parity (argmin, top-k, Pareto front, counts), async
double-buffered pipeline parity across prefetch depths, the backend
registry (``backend="pallas"`` in interpret mode and ``scan_chunks=4``
fused dispatch, both exact vs dense), compiled ``constraints=`` masking
vs the dense host post-filter, stacked-workload parity end-to-end, the
scenario engine (constant-trace degeneracy bitwise vs the static
kernel, the time-to-empty closed-form oracle, and session-channel
argmin/top-k(maximize) stream-vs-dense parity), and
the fault-tolerance recovery paths — a SIGKILLed checkpointed sweep
must resume in a fresh process with bitwise-identical results, and
seeded transient faults must retry to exact parity — so perf-path *and*
resilience regressions fail CI, not just benchmark runs.
"""

from __future__ import annotations

import argparse
import sys
import time


def dosc_advisor_rows():
    from repro.core import dosc
    out = []
    ranked = dosc.advise(grad_elems_per_chip=100e6, pods=2,
                         intra_pod_chips=256, objective="time")
    for c in ranked:
        out.append((f"dosc.{c.plan.name}.t_comm_ms", c.t_comm_s * 1e3,
                    f"dcn_edge={c.dcn_edge_bytes/2**20:.1f}MiB "
                    f"e={c.e_comm_j*1e3:.2f}mJ/chip"))
    flat = next(c for c in ranked if c.plan.name == "flat-ar-f32")
    best = ranked[0]
    out.append(("dosc.best_vs_flat_speedup",
                flat.t_comm_s / best.t_comm_s,
                f"best={best.plan.name} (the paper's two-tier insight)"))
    return out


SUITES = ["power_tables", "rbe_roofline", "tpu_roofline", "kernel_bench",
          "dosc_advisor", "sweep_bench", "pareto_bench", "stream_bench",
          "scenario_bench"]


def smoke_rows():
    """Fast streaming/dense parity gate for CI (tiny grids, asserts)."""
    import numpy as np

    from repro.core import pareto, partition, stream, sweep
    from repro.core.handtracking import build_detnet, build_keynet

    grid_kw = dict(sensor_nodes=("7nm", "16nm"),
                   weight_mems=("sram", "mram"),
                   detnet_fps=(5.0, 30.0))     # 34 cuts x 2x2x2 = 272
    dense = sweep.evaluate_grid(**grid_kw)
    res = stream.stream_grid(**grid_kw, chunk_size=97, track="all",
                             hist_bins=8)
    assert all(res.argmin(f) == dense.argmin(f) for f in sweep.FIELDS), \
        "streaming argmin drifted from dense"
    assert all(res.top_k(o) == dense.top_k(o, 4)
               for o in res.objectives), "streaming top-k drifted"
    df, sf = pareto.pareto_front(dense), res.pareto_front()
    assert np.array_equal(df.indices, sf.indices) and \
        np.array_equal(df.values, sf.values), "streaming front drifted"
    assert all(res.finite_counts[f] ==
               int(np.isfinite(dense.data[f]).sum())
               for f in sweep.FIELDS), "validity counts drifted"

    # Async double-buffered pipeline: prefetch depths (0 = synchronous
    # reference) must not change a single result.
    piped = stream.stream_grid(**grid_kw, chunk_size=97, prefetch=4)
    sync = stream.stream_grid(**grid_kw, chunk_size=97, prefetch=0)
    for r in (piped, sync):
        assert all(r.argmin(o) == dense.argmin(o)
                   for o in r.objectives), "async pipeline drifted"
        pf = r.pareto_front()
        assert np.array_equal(pf.indices, df.indices) and \
            np.array_equal(pf.values, df.values), "async front drifted"

    # Compiled constraint predicates == dense host post-filter, exactly.
    lat_budget = float(np.nanquantile(dense.data["latency"], 0.5))
    cons = {"latency": lat_budget}
    constrained = stream.stream_grid(**grid_kw, chunk_size=97,
                                    constraints=cons, prefetch=4)
    dense_con = dense.constrain(cons)
    assert constrained.argmin() == dense_con.argmin(), \
        "constrained argmin drifted from host post-filter"
    cf, dcf = constrained.pareto_front(), pareto.pareto_front(dense_con)
    assert np.array_equal(cf.indices, dcf.indices) and \
        np.array_equal(cf.values, dcf.values), "constrained front drifted"
    assert constrained.finite_counts["latency"] == \
        int(np.isfinite(dense_con.data["latency"]).sum()), \
        "feasible counts drifted"

    # Backend registry: the Pallas backend (interpret mode on CPU) and
    # scan-fused dispatch must reproduce the same grid exactly.
    pallas = stream.stream_grid(**grid_kw, chunk_size=97, track="all",
                                backend="pallas")
    assert all(pallas.argmin(f) == dense.argmin(f)
               for f in sweep.FIELDS), "pallas backend argmin drifted"
    assert all(pallas.top_k(o) == dense.top_k(o, 4)
               for o in pallas.objectives), "pallas backend top-k drifted"
    pf = pallas.pareto_front()
    assert np.array_equal(pf.indices, df.indices) and \
        np.array_equal(pf.values, df.values), "pallas front drifted"
    dense_pallas = sweep.evaluate_grid(**grid_kw, backend="pallas")
    assert all(np.array_equal(dense.data[f], dense_pallas.data[f],
                              equal_nan=True)
               for f in sweep.FIELDS), "pallas dense eval drifted"
    scanned = stream.stream_grid(**grid_kw, chunk_size=97, scan_chunks=4,
                                 prefetch=4)
    assert all(scanned.argmin(o) == dense.argmin(o)
               for o in scanned.objectives), "scan-fused argmin drifted"
    sc = scanned.pareto_front()
    assert np.array_equal(sc.indices, df.indices) and \
        np.array_equal(sc.values, df.values), "scan-fused front drifted"

    # Stacked-workload axis: every model row reproduces its own grid.
    det, key = build_detnet(), build_keynet()
    pairs = ((det, key), (det.scaled(0.5), key))
    stacked = sweep.evaluate_grid(models=pairs, detnet_fps=(10.0, 30.0))
    for mi, (d_wl, k_wl) in enumerate(pairs):
        single = sweep.evaluate_grid(detnet=d_wl, keynet=k_wl,
                                     detnet_fps=(10.0, 30.0))
        a, b = stacked.avg_power[mi], single.avg_power
        ok = np.isfinite(a) & np.isfinite(b)
        rel = np.abs(a[ok] - b[ok]) / np.maximum(np.abs(b[ok]), 1e-30)
        assert rel.max() <= 1e-6, f"stacked model {mi} drifted: {rel.max()}"

    # optimal_partition routes sequence knobs through the grid engines.
    best = partition.optimal_partition(sensor_node=("7nm", "16nm"))
    assert best.avg_power <= partition.optimal_partition().avg_power * (
        1 + 1e-12)

    # Scenario engine: the constant trace must degenerate bitwise to the
    # static kernel, the linear-battery time-to-empty closed form must
    # hold, and streaming session-channel reductions must match dense.
    from repro.core import scenario as SC
    from repro.core.constants import DEFAULT_BATTERY
    const = SC.ScenarioSet(
        traces=(SC.ScenarioTrace("const", (SC.Phase(600.0),)),),
        throttle=False)
    scen = sweep.evaluate_grid(**grid_kw, scenarios=const)
    assert all(np.array_equal(dense.data[f], scen.data[f][..., 0],
                              equal_nan=True) for f in sweep.FIELDS), \
        "constant-trace degeneracy drifted from the static kernel"
    P = scen.data["avg_power"][..., 0]
    okm = np.isfinite(P)
    tte_ref = DEFAULT_BATTERY.soc0 * DEFAULT_BATTERY.capacity_j / P[okm]
    tte_err = float(np.max(np.abs(
        scen.data["time_to_empty_s"][..., 0][okm] - tte_ref) / tte_ref))
    assert tte_err <= 1e-6, f"time-to-empty oracle drift: {tte_err}"
    scen_obj = ("time_to_empty_s", "peak_case_temp_c")
    scen_ref = sweep.evaluate_grid(**grid_kw, scenarios="all")
    scen_stream = stream.stream_grid(
        **grid_kw, scenarios="all", chunk_size=97, objectives=scen_obj,
        maximize=("time_to_empty_s",))
    assert scen_stream.argmin("peak_case_temp_c")["peak_case_temp_c"] == \
        np.nanmin(scen_ref.data["peak_case_temp_c"]), \
        "scenario streaming argmin drifted from dense"
    tr = scen_ref.data["time_to_empty_s"]
    assert scen_stream.top_k("time_to_empty_s")[0]["time_to_empty_s"] \
        == np.nanmax(tr[np.isfinite(tr)]), \
        "scenario top-k(maximize) drifted from dense"

    # Seeded transient faults (raise-on-chunk-k + Bernoulli rate): the
    # bounded retry path must converge with untouched results.
    from repro.runtime import FaultInjector, FaultPlan
    inj = FaultInjector(FaultPlan(fail_chunks=(1,), transient_rate=0.5,
                                  seed=3))
    faulted = stream.stream_grid(**grid_kw, chunk_size=97, track="all",
                                 fault_injector=inj)
    assert inj.injected["transient"] >= 1, "no transient faults fired"
    assert faulted.stats["retries"] == inj.injected["transient"], \
        "retry accounting drifted from injected fault count"
    assert all(faulted.argmin(f) == dense.argmin(f)
               for f in sweep.FIELDS), "retried sweep argmin drifted"
    ff = faulted.pareto_front()
    assert np.array_equal(ff.indices, df.indices) and \
        np.array_equal(ff.values, df.values), "retried sweep front drifted"
    n_retries = int(faulted.stats["retries"])

    # Kill-resume exact parity: SIGKILL a checkpointed sweep mid-flight
    # in a subprocess, then resume it in a fresh process and require
    # bitwise-identical deliverables.
    resumed_step = _smoke_kill_resume(grid_kw)

    return [
        ("smoke.stream_dense_parity", 1.0,
         f"argmin/top-k/front/counts exact on {dense.n_configs} configs"),
        ("smoke.async_pipeline_parity", 1.0,
         "prefetch 0/4 exact vs dense (double-buffered path)"),
        ("smoke.pallas_backend_parity", 1.0,
         "backend='pallas' (interpret) exact vs dense: stream + grid"),
        ("smoke.scan_fused_parity", 1.0,
         "scan_chunks=4 fused dispatch exact vs dense"),
        ("smoke.constrained_parity", 1.0,
         f"compiled latency<= {lat_budget:.3g} mask == dense post-filter"),
        ("smoke.stacked_parity", 1.0,
         f"{len(pairs)} stacked models <=1e-6 vs single grids"),
        ("smoke.scenario_oracle_parity", 1.0,
         f"const-trace degeneracy bitwise; tte oracle <= {tte_err:.2g}"),
        ("smoke.scenario_stream_parity", 1.0,
         f"session argmin/top-k(maximize) exact on "
         f"{scen_ref.n_configs} (config x trace)"),
        ("smoke.transient_fault_parity", 1.0,
         f"{n_retries} injected faults retried to exact parity"),
        ("smoke.kill_resume_parity", 1.0,
         f"SIGKILL at chunk 2 -> resumed from step {resumed_step} "
         f"bitwise-identical"),
        ("smoke.front_size", float(sf.size), "reference-front members"),
    ]


def _smoke_kill_resume(grid_kw: dict) -> int:
    """SIGKILL a checkpointed subprocess sweep, resume in a fresh one.

    Returns the resumed-from step index (> 0).  The resume child
    recomputes the dense reference itself and asserts bitwise parity on
    every deliverable, so the gate fails on any divergence, not just a
    crash."""
    import json
    import os
    import signal
    import subprocess
    import sys
    import tempfile

    with tempfile.TemporaryDirectory(prefix="smoke_ckpt_") as ckpt:
        common = f"""
import numpy as np
from repro.core import pareto, stream, sweep
GRID = {grid_kw!r}
KW = dict(chunk_size=97, top_k=4, track="all",
          checkpoint_dir={ckpt!r}, checkpoint_every_steps=1)
"""
        kill = common + """
from repro.runtime import FaultInjector, FaultPlan
inj = FaultInjector(FaultPlan(kill_at=2))
stream.stream_grid(**GRID, **KW, fault_injector=inj)
raise SystemExit("unreachable: SIGKILL did not fire")
"""
        resume = common + """
import json
dense = sweep.evaluate_grid(**GRID)
res = stream.stream_grid(**GRID, **KW)
assert res.stats["resumed_from_step"] > 0, res.stats
assert all(res.argmin(f) == dense.argmin(f) for f in sweep.FIELDS)
assert all(res.top_k(o) == dense.top_k(o, 4) for o in res.objectives)
df = pareto.pareto_front(dense); sf = res.pareto_front()
assert np.array_equal(df.indices, sf.indices)
assert np.array_equal(df.values, sf.values)
print(json.dumps({"resumed_from_step": res.stats["resumed_from_step"]}))
"""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
               if p])
        out1 = subprocess.run([sys.executable, "-c", kill], env=env,
                              capture_output=True, text=True, timeout=600)
        assert out1.returncode == -signal.SIGKILL, (
            f"kill child exited {out1.returncode}, expected SIGKILL: "
            f"{out1.stderr[-1000:]}")
        out2 = subprocess.run([sys.executable, "-c", resume], env=env,
                              capture_output=True, text=True, timeout=600)
        assert out2.returncode == 0, \
            f"resume child failed: {out2.stderr[-2000:]}"
        return int(json.loads(out2.stdout.strip().splitlines()[-1])
                   ["resumed_from_step"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=SUITES)
    ap.add_argument("--smoke", action="store_true",
                    help="fast streaming/dense parity gate (CI)")
    args = ap.parse_args()
    if args.smoke:
        print("name,value,derived")
        t0 = time.time()
        for name, val, derived in smoke_rows():
            print(f"{name},{val:.6g},{derived}")
        print(f"smoke.wall_s,{time.time()-t0:.1f},streaming parity gate")
        return
    suites = [args.only] if args.only else SUITES
    print("name,value,derived")
    t0 = time.time()
    failures = 0
    for s in suites:
        try:
            if s == "dosc_advisor":
                rows = dosc_advisor_rows()
            else:
                mod = __import__(f"benchmarks.{s}", fromlist=["rows"])
                rows = mod.rows()
            for name, val, derived in rows:
                print(f"{name},{val:.6g},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{s}.FAILED,0,{type(e).__name__}: {e}")
    print(f"benchmarks.wall_s,{time.time()-t0:.1f},"
          f"{len(suites)} suites, {failures} failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
