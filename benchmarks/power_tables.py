"""Benchmark: the paper's Fig. 5a / Fig. 5b power comparisons + Table 1/2.

Emits ``name,value,derived`` CSV rows: normalized system power for the
centralized vs distributed architectures, the hybrid-memory on-sensor
comparison, and the layer-granularity partition sweep (beyond-paper)."""

from __future__ import annotations

import time


def rows() -> list[tuple[str, float, str]]:
    from repro.core import partition, system
    from repro.core.constants import MIPI, UTSV
    from repro.core.handtracking import build_detnet

    out: list[tuple[str, float, str]] = []
    t0 = time.perf_counter()
    f5a = system.fig5a_comparison()
    f5b = system.fig5b_comparison()
    dt = (time.perf_counter() - t0) * 1e6

    out.append(("fig5a.centralized_A7", 1.0, "normalized power"))
    out.append(("fig5a.distributed_A7_O7", f5a["distributed[A=7nm,O=7nm]"],
                f"saving={f5a['_saving_7nm']*100:.1f}% (paper: 24%)"))
    out.append(("fig5a.distributed_A7_O16",
                f5a["distributed[A=7nm,O=16nm]"],
                f"saving={f5a['_saving_16nm']*100:.1f}% (paper: 16%)"))
    out.append(("fig5b.onsensor_sram", 1.0, "normalized power"))
    out.append(("fig5b.onsensor_hybrid_mram", f5b["hybrid"],
                f"saving={f5b['_saving']*100:.1f}% (paper: 39%)"))

    cen = system.build_centralized("7nm")
    bd = cen.breakdown()
    out.append(("fig5a.centralized_total_mw", cen.avg_power * 1e3,
                "absolute model output"))
    out.append(("fig5a.camera_mipi_share",
                (bd["camera"] + bd["mipi"]) / cen.avg_power,
                "paper: cameras+MIPIs dominate"))

    out.append(("table2.mipi_pj_per_byte", MIPI.energy_per_byte * 1e12,
                "paper: 100"))
    out.append(("table2.utsv_pj_per_byte", UTSV.energy_per_byte * 1e12,
                "paper: 5"))

    t0 = time.perf_counter()
    pts = partition.sweep_partitions()
    sweep_us = (time.perf_counter() - t0) * 1e6
    n_det = len(build_detnet().layers)
    best = min(pts, key=lambda p: p.avg_power)
    out.append(("partition.paper_split_saving",
                1 - pts[n_det].avg_power / pts[0].avg_power,
                "DetNet|KeyNet boundary (the paper's Fig. 2 choice)"))
    out.append(("partition.sweep_best_saving",
                1 - best.avg_power / pts[0].avg_power,
                f"beyond-paper layer-level optimum at cut {best.cut}"))
    out.append(("partition.sweep_eval_us", sweep_us,
                f"{len(pts)} cuts, semi-analytical"))
    out.append(("fig5_eval_us", dt, "full Fig.5 model eval"))
    return out


def main() -> None:
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
