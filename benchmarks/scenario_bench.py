"""Session scenario engine: (config x trace) sweep throughput + oracles.

    PYTHONPATH=src python -m benchmarks.scenario_bench

Benchmarks the battery/thermal session simulator
(``repro.core.scenario``) driven through the streaming executor:

* **oracle parity** — on a small reference grid, the constant-trace
  closed forms (time-to-empty, peak temperature, session energy) hold
  to <= 1e-6 and streaming argmin / top-k(maximize) / Pareto fronts
  over the session channels match the dense grid exactly;
* **million-pair throughput** — the acceptance-scale run: >= 10^6
  (config x trace) pairs streamed through ``stream_grid`` with
  ``objectives=("time_to_empty_s", "peak_case_temp_c")`` and
  ``maximize=("time_to_empty_s",)``, reporting pairs/s and the
  session-level winners.  Each pair runs the full per-session
  ``lax.scan`` (``n_steps`` Eq. 1-11 evaluations), so ``evals_per_s``
  records the underlying kernel-step rate for comparison against the
  static engines (``BENCH_sweep.json`` / ``BENCH_stream.json``).

Emits ``name,value,derived`` rows via :func:`rows` and snapshots
``BENCH_scenario.json`` at the repo root for the perf trail.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_scenario.json"

#: Small reference grid for exact stream/dense parity + oracles.
REF_GRID = dict(
    cuts=(0, 11, 20),
    sensor_nodes=("7nm", "16nm"),
    weight_mems=("sram", "mram"),
    detnet_fps=(5.0, 15.0, 30.0),
)

#: Acceptance-scale grid: 34 cuts x 2 x 2 x 26 x 72 = 254,592 configs,
#: x 4 profile traces = 1,018,368 (config x trace) pairs.
BIG_GRID = dict(
    sensor_nodes=("7nm", "16nm"),
    weight_mems=("sram", "mram"),
    detnet_fps=tuple(np.linspace(5.0, 30.0, 26)),
    camera_fps=tuple(np.linspace(20.0, 60.0, 72)),
)

OBJ = ("time_to_empty_s", "peak_case_temp_c")


def _oracle_rows():
    """Constant-trace closed forms + stream/dense parity on REF_GRID."""
    from repro.core import pareto, scenario as SC, stream, sweep
    from repro.core.constants import DEFAULT_BATTERY, DEFAULT_THERMAL

    D = 600.0
    const = SC.ScenarioSet(
        traces=(SC.ScenarioTrace("const", (SC.Phase(D),)),), throttle=False)
    dense = sweep.evaluate_grid(scenarios=const, **REF_GRID)
    P = dense.data["avg_power"][..., 0]
    ok = np.isfinite(P)

    def rel(got, ref):
        return float(np.max(np.abs(got[ok] - ref[ok])
                            / np.maximum(np.abs(ref[ok]), 1e-30)))

    tau = DEFAULT_THERMAL.r_th_k_per_w * DEFAULT_THERMAL.c_th_j_per_k
    errs = {
        "tte": rel(dense.data["time_to_empty_s"][..., 0],
                   DEFAULT_BATTERY.soc0 * DEFAULT_BATTERY.capacity_j / P),
        "peak": rel(dense.data["peak_case_temp_c"][..., 0],
                    DEFAULT_THERMAL.ambient_c + P
                    * DEFAULT_THERMAL.r_th_k_per_w
                    * (1.0 - np.exp(-D / tau))),
        "energy": rel(dense.data["session_energy_j"][..., 0], P * D),
    }
    assert max(errs.values()) <= 1e-6, f"oracle drift: {errs}"

    # constant-trace degeneracy: static channels bitwise vs plain grid
    static = sweep.evaluate_grid(**REF_GRID)
    assert all(np.array_equal(static.data[f], dense.data[f][..., 0],
                              equal_nan=True) for f in sweep.FIELDS), \
        "constant-trace degeneracy drifted from the static kernel"

    # stream/dense parity over the four profiles
    ref = sweep.evaluate_grid(scenarios="all", **REF_GRID)
    res = stream.stream_grid(objectives=OBJ, maximize=OBJ[:1],
                             scenarios="all", chunk_size=256, **REF_GRID)
    assert res.argmin("peak_case_temp_c")["peak_case_temp_c"] == \
        np.nanmin(ref.data["peak_case_temp_c"]), "scenario argmin drifted"
    tte = ref.data["time_to_empty_s"]
    want = np.sort(tte[np.isfinite(tte)])[::-1][:4]
    got = [p["time_to_empty_s"] for p in res.top_k("time_to_empty_s")]
    assert np.array_equal(got, want), "scenario top-k(maximize) drifted"
    df = pareto.pareto_front(ref, objectives=OBJ, maximize=OBJ[:1])
    sf = res.pareto_front()
    assert {tuple(v) for v in df.values} == \
        {tuple(v) for v in sf.values}, "scenario front drifted"

    return [
        ("scenario.oracle_max_rel_err", max(errs.values()),
         "tte/peak/energy closed forms on the constant trace"),
        ("scenario.stream_dense_parity", 1.0,
         f"argmin/top-k/front exact on {ref.n_configs} (config x trace)"),
        ("scenario.front_size", float(sf.size),
         "time-to-empty vs peak-temp front members"),
    ]


def _throughput_rows():
    from repro.core import scenario as SC, stream

    sset = SC.as_scenario_set("all")
    n_steps = max(len(t.phases) for t in sset.traces) * sset.steps_per_phase

    t0 = time.perf_counter()
    res = stream.stream_grid(objectives=OBJ, maximize=OBJ[:1],
                             scenarios=sset, **BIG_GRID)
    wall = time.perf_counter() - t0
    n = res.n_configs
    assert n >= 1_000_000, f"acceptance scale not reached: {n}"
    best = res.top_k("time_to_empty_s")[0]
    cool = res.argmin("peak_case_temp_c")

    point = {
        "n_pairs": int(n),
        "n_steps_per_session": int(n_steps),
        "wall_s": round(wall, 2),
        "pairs_per_s": round(n / wall, 1),
        "evals_per_s": round(n * n_steps / wall, 1),
        "best_tte_h": round(best["time_to_empty_s"] / 3600.0, 3),
        "best_tte_trace": best["trace"],
        "min_peak_c": round(cool["peak_case_temp_c"], 3),
        "front_size": int(res.pareto_front().size),
    }
    rows = [
        ("scenario.stream_1m.pairs", float(n),
         f"(config x trace) pairs, {n_steps}-step sessions"),
        ("scenario.stream_1m.pairs_per_s", point["pairs_per_s"],
         f"wall {wall:.1f}s through stream_grid"),
        ("scenario.stream_1m.evals_per_s", point["evals_per_s"],
         "underlying Eq. 1-11 kernel-step rate"),
        ("scenario.stream_1m.best_tte_h", point["best_tte_h"],
         f"max time-to-empty ({best['trace']}, cut={best['cut']})"),
        ("scenario.stream_1m.min_peak_c", point["min_peak_c"],
         f"coolest session ({cool['trace']}, cut={cool['cut']})"),
    ]
    return rows, point


def rows():
    out = _oracle_rows()
    tp_rows, point = _throughput_rows()
    out += tp_rows
    BENCH_JSON.write_text(json.dumps({
        "oracle": {name: val for name, val, _ in out[:3]},
        "stream_1m": point,
    }, indent=2) + "\n")
    return out


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")
