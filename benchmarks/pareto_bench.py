"""Pareto-front extraction + gradient knob-search throughput.

    PYTHONPATH=src python -m benchmarks.pareto_bench

Runs the multi-objective layer on the same 10,880-configuration grid as
``sweep_bench`` (so the perf trajectory has a shared reference point):

* front extraction over the three headline objectives (power, latency,
  MIPI traffic) — chunked O(n^2) dominance, configs/s;
* hypervolume + knee of the extracted front;
* the projected-Adam knob search of ``repro.core.optimize`` — steps/s
  post-jit (compile reported separately, not counted).

Emits ``name,value,derived`` rows via :func:`rows` and snapshots
``BENCH_pareto.json`` at the repo root for the perf trail.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.sweep_bench import GRID

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_pareto.json"

FRONT_REPS = 5     # timed repetitions of the full front extraction
OPT_STEPS = 150    # projected-Adam steps in the timed search
OPT_BOUNDS = {"detnet_fps": (5.0, 30.0), "camera_fps": (20.0, 60.0)}
OPT_OBJECTIVE = {"avg_power": 1.0, "latency": 10.0}


def rows():
    from repro.core import optimize, pareto, sweep
    from repro.core.handtracking import build_detnet

    n_det = len(build_detnet().layers)

    # --- the grid itself is sweep_bench's; its eval time is not ours ---
    res = sweep.evaluate_grid(**GRID)
    n = res.n_configs
    assert n >= 10_000, n

    t0 = time.perf_counter()
    for _ in range(FRONT_REPS):
        front = pareto.pareto_front(res)
    front_cps = FRONT_REPS * n / (time.perf_counter() - t0)

    t0 = time.perf_counter()
    hv = front.hypervolume()
    hv_s = time.perf_counter() - t0
    knee = front.knee()

    # --- gradient search: compile once, then time the steady state ---
    opt_kw = dict(cut=n_det, sensor_node="16nm", steps=OPT_STEPS)
    t0 = time.perf_counter()
    optimize.optimize_knobs(OPT_BOUNDS, OPT_OBJECTIVE, **opt_kw)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    opt = optimize.optimize_knobs(OPT_BOUNDS, OPT_OBJECTIVE, **opt_kw)
    opt_sps = OPT_STEPS / (time.perf_counter() - t0)

    snapshot = {
        "grid_configs": n,
        "front_size": front.size,
        "front_configs_per_s": round(front_cps, 1),
        "hypervolume": hv,
        "hypervolume_s": round(hv_s, 4),
        "knee": {k: (int(v) if isinstance(v, (int, np.integer))
                     else float(v) if isinstance(v, (float, np.floating))
                     else v) for k, v in knee.items()},
        "opt_steps_per_s": round(opt_sps, 1),
        "opt_compile_s": round(compile_s, 3),
        "opt_knobs": {k: round(float(v), 4) for k, v in opt.knobs.items()},
        "opt_objective": opt.objective,
    }
    BENCH_JSON.write_text(json.dumps(snapshot, indent=2) + "\n")

    return [
        ("pareto.grid_configs", float(n), "shared sweep_bench grid"),
        ("pareto.front_size", float(front.size),
         f"objectives={','.join(front.objectives)}"),
        ("pareto.front_configs_per_s", front_cps,
         f"lexsort + running-front cull x{FRONT_REPS}"),
        ("pareto.hypervolume", hv,
         f"grid-nadir ref, {hv_s*1e3:.1f} ms"),
        ("pareto.knee_power_mw", knee["avg_power"] * 1e3,
         f"cut={knee['cut']} lat={knee['latency']*1e3:.2f}ms "
         f"mipi={knee['mipi_bytes_per_s']/1e6:.2f}MB/s"),
        ("optimize.steps_per_s", opt_sps,
         f"projected Adam, {len(OPT_BOUNDS)} knobs "
         f"(compile {compile_s:.2f}s)"),
        ("optimize.best_objective_mw", opt.objective * 1e3,
         " ".join(f"{k}={v:.2f}" for k, v in opt.knobs.items())),
    ]


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")
    print(f"(snapshot written to {BENCH_JSON})")
