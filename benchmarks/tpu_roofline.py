"""Benchmark: the 40-cell TPU roofline table (from dry-run artifacts).

Reads ``experiments/dryrun_results.json`` (produced by
``python -m repro.launch.dryrun --all --both-meshes``) and emits the
single-pod roofline terms per (arch x shape) plus the adapted
semi-analytical energy estimate — the paper's Eq. 1/2 lifted to TPU pods.
"""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun_results.json")


def rows():
    if not os.path.exists(RESULTS):
        return [("tpu_roofline.missing", 0.0,
                 "run: python -m repro.launch.dryrun --all --both-meshes")]
    with open(RESULTS) as f:
        results = json.load(f)
    out = []
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    out.append(("dryrun.cells_ok", n_ok, f"{n_skip} documented skips, "
                f"{n_err} errors"))
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"],
                                            r["mesh"])):
        cell = f"{r['arch']}.{r['shape']}.{r['mesh']}"
        if r["status"] == "skipped":
            out.append((f"cell.{cell}.skipped", 1.0, r["reason"][:60]))
            continue
        if r["status"] != "ok":
            out.append((f"cell.{cell}.error", 1.0,
                        r.get("error", "?")[:60]))
            continue
        if r["mesh"] != "16x16":
            continue   # roofline table is single-pod; multi-pod = compile proof
        rf = r["roofline"]
        out.append((
            f"cell.{cell}.t_bound_ms", rf["t_bound"] * 1e3,
            f"dom={rf['dominant']} comp={rf['t_compute']*1e3:.1f} "
            f"mem={rf['t_memory']*1e3:.1f} coll={rf['t_collective']*1e3:.1f} "
            f"useful={rf['useful_flops_ratio']:.3f} "
            f"roofline={rf['roofline_fraction']*100:.2f}%"))
        out.append((
            f"cell.{cell}.energy_j", r["energy_per_step_j"]["total"],
            f"sys_power={r['est_system_power_w']/1e3:.1f}kW "
            f"(Eq.1/2 TPU-adapted)"))
    # multi-pod compile proof
    mp_ok = sum(1 for r in results
                if r["status"] == "ok" and r["mesh"] == "2x16x16")
    out.append(("dryrun.multipod_cells_ok", mp_ok,
                "2x16x16 (pod,data,model) lower+compile proof"))
    return out


def main() -> None:
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
