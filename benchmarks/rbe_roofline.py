"""Benchmark: the paper's Fig. 4 — RBE accelerator roofline.

Characterizes representative Regular / Pointwise / Depthwise convolutions
(as the paper does with GVSoC) and reports streamed-weight arithmetic
intensity, effective MAC/cycle and the binding constraint per layer."""

from __future__ import annotations


def rows():
    from repro.core import rbe
    from repro.core.constants import RBE
    from repro.core.handtracking import build_detnet, build_keynet
    from repro.core.workloads import conv2d, depthwise, pointwise

    out = []
    # the paper's layer sweep: kinds x channel/spatial variations
    sweep = []
    for c in (32, 96, 192):
        sweep.append(conv2d(f"conv3x3_c{c}", 40, 30, c, c, k=3))
        sweep.append(pointwise(f"pointwise_c{c}", 40, 30, c, c))
        sweep.append(depthwise(f"depthwise_c{c}", 40, 30, c))
    for layer in sweep:
        eff = rbe.mac_per_cycle(layer, RBE)
        out.append((f"fig4.{layer.name}.mac_per_cycle", eff,
                    f"AI={rbe.streamed_intensity(layer):.1f} MAC/B, "
                    f"peak={RBE.peak_mac_per_cycle}"))
    # orderings the paper reports
    conv = rbe.mac_per_cycle(conv2d("c", 40, 30, 96, 96, k=3), RBE)
    pw = rbe.mac_per_cycle(pointwise("p", 40, 30, 96, 96), RBE)
    dw = rbe.mac_per_cycle(depthwise("d", 40, 30, 96), RBE)
    out.append(("fig4.ordering_conv_gt_pw_gt_dw",
                float(conv > pw > dw), "paper: conv > pointwise > depthwise"))
    pts = (rbe.roofline_points(build_detnet())
           + rbe.roofline_points(build_keynet()))
    n_ws = sum(1 for p in pts if p.bound == "weight-stream")
    out.append(("fig4.weight_stream_bound_layers", n_ws,
                f"of {len(pts)} hand-tracking layers (paper: 'several')"))
    return out


def main() -> None:
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
