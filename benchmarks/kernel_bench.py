"""Benchmark: Pallas kernels (interpret-mode correctness + timing) and the
lowering-path flash attention vs the naive reference.

Interpret-mode wall times are NOT TPU times (the kernel body runs in
Python); they are reported for regression tracking only.  The derived
column carries the analytic VMEM working set per kernel instance — the
quantity that must stay under the ~16 MiB/core budget on the TPU target.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def rows():
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)
    from repro.kernels.rbe_matmul import rbe_matmul
    from repro.kernels.rmsnorm import rmsnorm

    out = []
    ks = jax.random.split(jax.random.key(0), 3)

    # flash attention: VMEM working set per (b, kv_head, q_blk) instance
    b, s, h, kv, d, bq, bk = 1, 512, 4, 2, 128, 128, 128
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    g = h // kv
    vmem = (bq * g * d * 4 + 2 * s * d * 4 + bq * g * bk * 4
            + bq * g * d * 4) / 2**20
    us = _time(lambda: flash_attention(q, k, v, block_q=bq, block_kv=bk))
    err = float(jnp.max(jnp.abs(
        flash_attention(q, k, v, block_q=bq, block_kv=bk)
        - flash_attention_ref(q, k, v))))
    out.append(("kernel.flash_attention.us_per_call", us,
                f"interpret; vmem/inst={vmem:.2f}MiB err={err:.1e}"))

    # rbe matmul
    m = n = kk = 512
    x = jax.random.normal(ks[0], (m, kk), jnp.float32)
    w = jax.random.normal(ks[1], (kk, n), jnp.float32)
    us = _time(lambda: rbe_matmul(x, w))
    vmem = (128 * kk + kk * 128 + 128 * 128 * 4) / 2**20
    out.append(("kernel.rbe_matmul.us_per_call", us,
                f"interpret; int8 128x128x128 tiles, "
                f"vmem/inst={vmem:.2f}MiB"))

    # rmsnorm
    x = jax.random.normal(ks[0], (4096, 1024), jnp.float32)
    sc = jnp.zeros((1024,))
    us = _time(lambda: rmsnorm(x, sc))
    out.append(("kernel.rmsnorm.us_per_call", us,
                f"interpret; {256*1024*4/2**20:.1f}MiB/inst"))

    # lowering-path flash (the one the dry-run compiles) vs naive oracle
    from repro.models.attention import full_attention_reference
    from repro.models.flash import flash_attention as model_flash
    f1 = jax.jit(lambda q, k, v: model_flash(q, k, v, q_block=128,
                                             kv_block=128))
    f2 = jax.jit(lambda q, k, v: full_attention_reference(q, k, v))
    us1 = _time(lambda: f1(q, k, v))
    us2 = _time(lambda: f2(q, k, v))
    out.append(("model.flash_vjp.us_per_call", us1,
                f"vs naive {us2:.0f}us (CPU; memory win is the point)"))
    return out


def main() -> None:
    for name, val, derived in rows():
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
