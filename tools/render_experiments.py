"""Render the data-driven sections of EXPERIMENTS.md from result JSONs.

    PYTHONPATH=src python tools/render_experiments.py > experiments/tables.md

The generated tables are pasted into EXPERIMENTS.md (kept separate so the
narrative sections are hand-written while numbers stay reproducible).
"""

from __future__ import annotations

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun_results.json")
HILL = os.path.join(ROOT, "experiments", "hillclimb_results.json")


def fmt_bytes(n):
    return f"{n/2**30:.2f}"


def roofline_tables():
    rows = json.load(open(DRY))
    ok = [r for r in rows if r["status"] == "ok"
          and r.get("tag", "baseline") == "baseline"]
    print("### Single-pod (16x16 = 256 chips) baseline roofline — all "
          "cells\n")
    print("| arch | shape | mode | t_comp (ms) | t_mem (ms) | t_coll (ms) "
          "| dominant | MODEL_FLOPS/HLO | roofline frac | E/step (J) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            continue
        rf = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {r['mode']} "
              f"| {rf['t_compute']*1e3:.1f} | {rf['t_memory']*1e3:.1f} "
              f"| {rf['t_collective']*1e3:.1f} | {rf['dominant']} "
              f"| {rf['useful_flops_ratio']:.3f} "
              f"| {rf['roofline_fraction']*100:.2f}% "
              f"| {r['energy_per_step_j']['total']:.1f} |")
    skips = [r for r in rows if r["status"] == "skipped"
             and r["mesh"] == "16x16"]
    print("\nSkipped cells (documented):\n")
    for r in skips:
        print(f"* `{r['arch']} x {r['shape']}` — {r['reason']}")
    print("\n### Multi-pod (2x16x16 = 512 chips) compile proof\n")
    mp = [r for r in rows if r["mesh"] == "2x16x16"]
    n_ok = sum(1 for r in mp if r["status"] == "ok")
    n_sk = sum(1 for r in mp if r["status"] == "skipped")
    print(f"{n_ok} cells lower+compile OK, {n_sk} documented skips, "
          f"{sum(1 for r in mp if r['status']=='error')} errors.\n")
    print("| arch | shape | t_bound (ms) | dominant | DCN-tier wire bytes "
          "| state/dev (GiB) |")
    print("|---|---|---|---|---|---|")
    for r in sorted(mp, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        # DCN tier = collectives with group size > intra-pod chips (256)
        print(f"| {r['arch']} | {r['shape']} | {rf['t_bound']*1e3:.1f} "
              f"| {rf['dominant']} "
              f"| {r['collective_wire_bytes']:.2e} "
              f"| {fmt_bytes(r['state_bytes_per_device'])} |")


def memory_tables():
    rows = json.load(open(DRY))
    print("\n### Dry-run memory analysis (single-pod, per device)\n")
    print("| arch | shape | args (GiB) | temps (GiB) | state-analytic "
          "(GiB) | fits 16 GiB HBM |")
    print("|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r["mesh"] != "16x16" \
                or r.get("tag", "baseline") != "baseline":
            continue
        m = r["memory_analysis"]
        if "argument_bytes" not in m:
            continue
        args = m["argument_bytes"] / 2**30
        temps = m["temp_bytes"] / 2**30
        state = r["state_bytes_per_device"] / 2**30
        total = state + temps
        print(f"| {r['arch']} | {r['shape']} | {args:.2f} | {temps:.2f} "
              f"| {state:.2f} | {'yes' if total < 16 else 'NO'} |")


def hillclimb_tables():
    if not os.path.exists(HILL):
        return
    rows = json.load(open(HILL))
    base = {(r["arch"], r["shape"]): r
            for r in json.load(open(DRY))
            if r["status"] == "ok" and r["mesh"] == "16x16"
            and r.get("tag", "baseline") == "baseline"}
    print("\n### §Perf hillclimb iterations\n")
    print("| cell | variant | t_comp | t_mem | t_coll | bound (ms) "
          "| useful | roofline |")
    print("|---|---|---|---|---|---|---|---|")
    seen = set()
    for r in rows:
        key = (r["arch"], r["shape"])
        if key not in seen and key in base:
            seen.add(key)
            b = base[key]
            rf = b["roofline"]
            print(f"| {r['arch']} x {r['shape']} | **baseline (16x16)** "
                  f"| {rf['t_compute']*1e3:.1f} | {rf['t_memory']*1e3:.1f} "
                  f"| {rf['t_collective']*1e3:.1f} "
                  f"| {rf['t_bound']*1e3:.1f} "
                  f"| {rf['useful_flops_ratio']:.3f} "
                  f"| {rf['roofline_fraction']*100:.2f}% |")
        if r["status"] != "ok":
            print(f"| {r['arch']} x {r['shape']} | {r['tag']} "
                  f"| - | - | - | - | - | {r['status']} |")
            continue
        rf = r["roofline"]
        print(f"| {r['arch']} x {r['shape']} | {r['tag']} ({r['mesh']}) "
              f"| {rf['t_compute']*1e3:.1f} | {rf['t_memory']*1e3:.1f} "
              f"| {rf['t_collective']*1e3:.1f} | {rf['t_bound']*1e3:.1f} "
              f"| {rf['useful_flops_ratio']:.3f} "
              f"| {rf['roofline_fraction']*100:.2f}% |")


if __name__ == "__main__":
    roofline_tables()
    memory_tables()
    hillclimb_tables()
