"""Calibrate unpublished silicon constants to the paper's headline results.

The paper gives Table 1 (camera) and Table 2 (links) but only *describes* the
MAC/memory constants ("post-synthesis simulations and memory compilers").
This script searches literature-plausible ranges for those constants so the
model reproduces:

    Fig. 5a: 24% saving (dist 7nm), 16% saving (dist 16nm on-sensor)
    Fig. 5b: 39% on-sensor saving (hybrid MRAM vs SRAM, 16nm, 10 fps)

subject to qualitative constraints the paper states:
    * cameras + MIPI dominate the centralized system power;
    * total memory power increases only slightly under distribution.

Run:  PYTHONPATH=src python tools/calibrate_constants.py
The winning parameters are printed and then baked into
src/repro/core/constants.py by hand (with provenance comments).
"""

from __future__ import annotations

import dataclasses
import sys

import numpy as np

from repro.core import system
from repro.core.constants import (MRAM_16NM, NODE_16NM, NODE_7NM, MemorySpec,
                                  TechNode)

MIB = float(1 << 20)

rng = np.random.default_rng(0)

TARGETS = dict(s7=0.24, s16=0.16, fb=0.39)


def make_nodes(p):
    sram16 = MemorySpec("SRAM-16nm", e_read=0.80e-12, e_write=1.00e-12,
                        leak_on=p["lk16"] / MIB,
                        leak_ret=p["lk16"] * p["rret"] / MIB)
    sram7 = MemorySpec("SRAM-7nm", e_read=0.50e-12, e_write=0.65e-12,
                       leak_on=p["lk16"] * p["r7"] / MIB,
                       leak_ret=p["lk16"] * p["r7"] * p["rret"] / MIB)
    mram16 = dataclasses.replace(MRAM_16NM,
                                 leak_on=p["lk16"] * 0.03 / MIB, leak_ret=0.0)
    n16 = TechNode("16nm", e_mac=p["em7"] * p["emr"], f_clk=500e6,
                   sram=sram16, mram=mram16)
    n7 = TechNode("7nm", e_mac=p["em7"], f_clk=700e6, sram=sram7, mram=None)
    return n7, n16


def evaluate(p):
    n7, n16 = make_nodes(p)
    ts = p["tsense"]
    cen = system.build_centralized(n7, t_sense=ts)
    d77 = system.build_distributed(n7, n7, t_sense=ts)
    d716 = system.build_distributed(n7, n16, t_sense=ts)
    base = cen.avg_power
    s7 = 1 - d77.avg_power / base
    s16 = 1 - d716.avg_power / base

    def onsensor(mem):
        rep = system.build_distributed(n7, n16, sensor_weight_mem=mem,
                                       detnet_fps=10.0, t_sense=ts)
        return rep.group_power("sensor")

    fb = 1 - onsensor("mram") / onsensor("sram")

    # qualitative constraints
    bd = cen.breakdown()
    cam_mipi = bd.get("camera", 0) + bd.get("mipi", 0)
    dom = cam_mipi / base  # should be > 0.5 ("cameras and MIPIs dominate")
    mem_c = cen.group_power("agg.memory")
    mem_d = (d77.group_power("agg.memory")
             + d77.group_power("sensor0.memory") * 4)
    dmem = (mem_d - mem_c) / base  # small positive ("slightly increases")

    loss = ((s7 - TARGETS["s7"]) ** 2 + (s16 - TARGETS["s16"]) ** 2
            + (fb - TARGETS["fb"]) ** 2)
    if dom < 0.55:
        loss += (0.55 - dom) ** 2 * 10
    if dmem < 0.0:
        loss += dmem ** 2 * 10
    if dmem > 0.08:
        loss += (dmem - 0.08) ** 2 * 10
    return loss, dict(s7=s7, s16=s16, fb=fb, dom=dom, dmem=dmem,
                      base_mw=base * 1e3)


BOUNDS = {
    "tsense": (1.0e-3, 7e-3),    # exposure+ADC window
    "lk16": (0.5e-3, 6.0e-3),    # 16nm SRAM active leakage, W/MiB
    "rret": (0.20, 0.70),        # retention:active leakage ratio
    "r7": (0.55, 1.0),           # 7nm:16nm SRAM leakage ratio
    "em7": (0.10e-12, 0.55e-12),   # 7nm J/MAC
    "emr": (1.5, 2.2),             # 16nm:7nm MAC energy ratio (node scaling)
}


def sample():
    p = {k: rng.uniform(*v) for k, v in BOUNDS.items()}
    return p


def main(n_random=4000, n_refine=60):
    best, bp, bm = np.inf, None, None
    for _ in range(n_random):
        p = sample()
        loss, m = evaluate(p)
        if loss < best:
            best, bp, bm = loss, p, m
    # coordinate refinement
    for _ in range(n_refine):
        for k in BOUNDS:
            lo, hi = BOUNDS[k]
            for mult in (0.9, 0.95, 1.05, 1.1):
                q = dict(bp)
                q[k] = float(np.clip(bp[k] * mult, lo, hi))
                loss, m = evaluate(q)
                if loss < best:
                    best, bp, bm = loss, q, m
    print("loss:", best)
    for k, v in bp.items():
        print(f"  {k:8s} = {v:.6e}")
    for k, v in bm.items():
        print(f"  {k:8s} : {v:.4f}")


if __name__ == "__main__":
    main()
